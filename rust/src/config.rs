//! Run configuration: the knobs of one federated training run, mirroring
//! the paper's hyper-parameter table (Supp. Table 6).

/// Which FL optimizer drives the run (Table 3 compatibility set).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Optimizer {
    /// FedAvg (McMahan et al. 2017) — the backbone for all main results.
    FedAvg,
    /// FedProx (Li et al. 2020) with proximal coefficient μ.
    FedProx { mu: f32 },
    /// SCAFFOLD (Karimireddy et al. 2020), Option II control variates.
    Scaffold,
    /// FedDyn (Acar et al. 2021) with regularization α.
    FedDyn { alpha: f32 },
    /// FedAdam (Reddi et al. 2021) — server-side Adam.
    FedAdam,
}

impl Optimizer {
    /// Parse an optimizer spec. Hyperparameterized optimizers accept an
    /// explicit value — `fedprox:<mu>` / `feddyn:<alpha>` — and fall back
    /// to the paper's μ = α = 0.1 when given just the bare name.
    pub fn parse(s: &str) -> Result<Optimizer, String> {
        let (kind, arg) = match s.split_once(':') {
            Some((k, a)) => (k, Some(a)),
            None => (s, None),
        };
        let parse_param = |what: &str, default: f32| -> Result<f32, String> {
            match arg {
                None => Ok(default),
                Some(a) => {
                    let v: f32 = a
                        .parse()
                        .map_err(|_| format!("optimizer '{kind}': {what} '{a}' is not a number"))?;
                    if !v.is_finite() || v < 0.0 {
                        return Err(format!(
                            "optimizer '{kind}': {what} must be finite and >= 0, got '{a}'"
                        ));
                    }
                    Ok(v)
                }
            }
        };
        let no_param = |opt: Optimizer| -> Result<Optimizer, String> {
            match arg {
                None => Ok(opt),
                Some(a) => Err(format!("optimizer '{kind}' takes no parameter (got ':{a}')")),
            }
        };
        match kind {
            "fedavg" => no_param(Optimizer::FedAvg),
            "fedprox" => Ok(Optimizer::FedProx { mu: parse_param("mu", 0.1)? }),
            "scaffold" => no_param(Optimizer::Scaffold),
            "feddyn" => Ok(Optimizer::FedDyn { alpha: parse_param("alpha", 0.1)? }),
            "fedadam" => no_param(Optimizer::FedAdam),
            other => Err(format!("unknown optimizer '{other}'")),
        }
    }

    /// Canonical spec string; `parse(spec_string())` round-trips exactly.
    pub fn spec_string(&self) -> String {
        match self {
            Optimizer::FedAvg => "fedavg".into(),
            Optimizer::FedProx { mu } => format!("fedprox:{mu}"),
            Optimizer::Scaffold => "scaffold".into(),
            Optimizer::FedDyn { alpha } => format!("feddyn:{alpha}"),
            Optimizer::FedAdam => "fedadam".into(),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Optimizer::FedAvg => "FedAvg",
            Optimizer::FedProx { .. } => "FedProx",
            Optimizer::Scaffold => "SCAFFOLD",
            Optimizer::FedDyn { .. } => "FedDyn",
            Optimizer::FedAdam => "FedAdam",
        }
    }
}

/// What part of the model is shared with the server.
#[derive(Clone, Debug, PartialEq)]
pub enum Sharing {
    /// Everything is transferred (FedAvg/FedPara default).
    Full,
    /// Only the layout's `global` segments travel (pFedPara, §2.3).
    GlobalSegments,
    /// FedPer (Arivazhagan et al. 2019): segments whose name starts with
    /// one of these prefixes stay local; the rest is transferred.
    FedPer { local_prefixes: Vec<String> },
    /// No communication after init — the Figure-5 "FedPAQ/local-only"
    /// baseline (each client trains alone).
    LocalOnly,
}

impl Sharing {
    /// Parse a sharing spec: `full`, `pfedpara` (alias `global-segments`),
    /// `local-only`, or `fedper:<prefix,...>` with comma-separated segment
    /// name prefixes that stay local (e.g. `fedper:fc2`).
    pub fn parse(s: &str) -> Result<Sharing, String> {
        match s {
            "full" => Ok(Sharing::Full),
            "pfedpara" | "global-segments" => Ok(Sharing::GlobalSegments),
            "local-only" => Ok(Sharing::LocalOnly),
            "fedper" => Err("fedper needs local prefixes: fedper:<prefix,...>".into()),
            _ => match s.strip_prefix("fedper:") {
                Some(rest) => {
                    let prefixes: Vec<String> = rest
                        .split(',')
                        .map(|p| p.trim().to_string())
                        .filter(|p| !p.is_empty())
                        .collect();
                    if prefixes.is_empty() {
                        return Err("fedper needs at least one non-empty prefix".into());
                    }
                    Ok(Sharing::FedPer { local_prefixes: prefixes })
                }
                None => Err(format!(
                    "unknown sharing '{s}' (full|pfedpara|local-only|fedper:<prefix,...>)"
                )),
            },
        }
    }

    /// Canonical spec string; `parse(spec_string())` round-trips exactly.
    pub fn spec_string(&self) -> String {
        match self {
            Sharing::Full => "full".into(),
            Sharing::GlobalSegments => "pfedpara".into(),
            Sharing::FedPer { local_prefixes } => format!("fedper:{}", local_prefixes.join(",")),
            Sharing::LocalOnly => "local-only".into(),
        }
    }
}

/// One wire codec: how a dense f32 vector is represented on the simulated
/// network. Specs are declarative (parse/spec_string round-trip, manifest
/// serializable); the actual encoders live in [`crate::coordinator::wire`].
#[derive(Clone, Debug, PartialEq)]
pub enum CodecSpec {
    /// Raw fp32 — 4 bytes/value, bit-exact.
    Identity,
    /// FedPAQ-style fp16 round-to-nearest-even (Supp. D.3) — 2 bytes/value.
    Fp16,
    /// Konečný et al. (2016) sketched update: transmit a random `rate`
    /// subset of coordinates, each probabilistically quantized to one of
    /// `levels` levels over the subset's [min, max] range. Uplink-only:
    /// the sketch delta-codes against the global the client received, and
    /// (when `feedback` is on — the default) an error-feedback accumulator
    /// persisted per client in the `ClientStore` carries the untransmitted
    /// mass so aggressive rates don't diverge. `feedback: false` is the
    /// ablation arm kept for the divergence comparison.
    SubsampleQuant { rate: f64, levels: u32, feedback: bool },
}

impl CodecSpec {
    /// Parse a codec spec: `identity`, `fp16`, or
    /// `subsample_quant:<rate>[:<levels>][:nofb]` (levels default 16;
    /// `nofb` disables the error-feedback accumulator — the ablation arm).
    pub fn parse(s: &str) -> Result<CodecSpec, String> {
        match s {
            "identity" => return Ok(CodecSpec::Identity),
            "fp16" => return Ok(CodecSpec::Fp16),
            "subsample_quant" => {
                return Err(
                    "subsample_quant needs a rate: subsample_quant:<rate>[:<levels>][:nofb]".into()
                )
            }
            _ => {}
        }
        let Some(rest) = s.strip_prefix("subsample_quant:") else {
            return Err(format!(
                "unknown codec '{s}' (identity|fp16|subsample_quant:<rate>[:<levels>][:nofb])"
            ));
        };
        let mut parts = rest.split(':');
        let rate_s = parts.next().unwrap_or("");
        let rate: f64 = rate_s
            .parse()
            .map_err(|_| format!("subsample_quant: rate '{rate_s}' is not a number"))?;
        let mut levels = 16u32;
        let mut feedback = true;
        match parts.next() {
            None => {}
            Some("nofb") => feedback = false,
            Some(l) => {
                levels = l
                    .parse()
                    .map_err(|_| format!("subsample_quant: levels '{l}' is not an integer"))?;
                match parts.next() {
                    None => {}
                    Some("nofb") => feedback = false,
                    Some(x) => {
                        return Err(format!("subsample_quant: unexpected trailing ':{x}'"))
                    }
                }
            }
        }
        if parts.next().is_some() {
            return Err(format!("subsample_quant: too many ':'-separated fields in '{s}'"));
        }
        let spec = CodecSpec::SubsampleQuant { rate, levels, feedback };
        spec.validate()?;
        Ok(spec)
    }

    /// Canonical spec string; `parse(spec_string())` round-trips exactly.
    pub fn spec_string(&self) -> String {
        match self {
            CodecSpec::Identity => "identity".into(),
            CodecSpec::Fp16 => "fp16".into(),
            CodecSpec::SubsampleQuant { rate, levels, feedback: true } => {
                format!("subsample_quant:{rate}:{levels}")
            }
            CodecSpec::SubsampleQuant { rate, levels, feedback: false } => {
                format!("subsample_quant:{rate}:{levels}:nofb")
            }
        }
    }

    /// Range checks shared by `parse` and the manifest validator.
    pub fn validate(&self) -> Result<(), String> {
        if let CodecSpec::SubsampleQuant { rate, levels, .. } = self {
            if !rate.is_finite() || *rate <= 0.0 || *rate > 1.0 {
                return Err(format!("subsample_quant: rate must be in (0, 1], got {rate}"));
            }
            if !(2..=256).contains(levels) {
                return Err(format!(
                    "subsample_quant: levels must be in [2, 256] (one wire byte), got {levels}"
                ));
            }
        }
        Ok(())
    }

    /// True when the codec consults a per-client error-feedback accumulator.
    pub fn uses_feedback(&self) -> bool {
        matches!(self, CodecSpec::SubsampleQuant { feedback: true, .. })
    }
}

/// The wire model of one run: what each direction of the simulated network
/// does to the bytes crossing it.
#[derive(Clone, Debug, PartialEq)]
pub struct WireConfig {
    /// Client→server codec applied to every upload (model and SCAFFOLD
    /// side-state alike).
    pub up: CodecSpec,
    /// Server→client codec applied to the per-round broadcast global.
    /// `subsample_quant` is rejected here: the sketch delta-codes against
    /// receiver state a broadcast cannot assume.
    pub down: CodecSpec,
    /// Content-fingerprinted downloads: the store tracks the hash of the
    /// last global each client received, and a client that already holds
    /// the current global is billed only the 32-byte hash check instead of
    /// a full redelivery. Changes billing only — never training bits.
    pub fingerprint_downloads: bool,
}

impl WireConfig {
    /// The identity wire: raw fp32 both ways, every download redelivered.
    pub fn identity() -> WireConfig {
        WireConfig {
            up: CodecSpec::Identity,
            down: CodecSpec::Identity,
            fingerprint_downloads: false,
        }
    }

    /// The legacy `quantize_upload` rung: fp16 uploads, raw downloads.
    pub fn fp16_up() -> WireConfig {
        WireConfig { up: CodecSpec::Fp16, ..WireConfig::identity() }
    }

    /// Joint validity: per-codec ranges plus direction constraints.
    pub fn validate(&self) -> Result<(), String> {
        self.up.validate()?;
        self.down.validate()?;
        if matches!(self.down, CodecSpec::SubsampleQuant { .. }) {
            return Err(
                "wire.down: subsample_quant is an uplink codec (the sketch delta-codes \
                 against per-client receiver state, which a broadcast downlink cannot \
                 assume); use identity or fp16"
                    .into(),
            );
        }
        Ok(())
    }
}

impl Default for WireConfig {
    fn default() -> WireConfig {
        WireConfig::identity()
    }
}

/// One federated run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Manifest artifact name (model × scheme × γ).
    pub artifact: String,
    /// Fraction of clients sampled each round (paper: 0.16).
    pub sample_frac: f64,
    /// Total rounds T.
    pub rounds: usize,
    /// Local epochs E per selected client per round.
    pub local_epochs: usize,
    /// Initial learning rate η.
    pub lr: f32,
    /// Multiplicative per-round lr decay τ (paper: 0.992).
    pub lr_decay: f64,
    pub optimizer: Optimizer,
    /// The wire model: up/down codecs + fingerprint-cached downloads.
    /// (The old `quantize_upload: true` is exactly `WireConfig::fp16_up()`.)
    pub wire: WireConfig,
    pub sharing: Sharing,
    /// Evaluate the global model every `eval_every` rounds (0 = only final).
    pub eval_every: usize,
    pub seed: u64,
    /// Worker threads for the per-round client fan-out (0 = size the pool
    /// to the host). Results are bit-identical for every pool size: client
    /// RNG streams are keyed by `(round, cid)` and the reduce folds
    /// outcomes in participant order regardless of completion order.
    pub num_threads: usize,
}

impl Default for RunConfig {
    fn default() -> RunConfig {
        RunConfig {
            artifact: String::new(),
            sample_frac: 0.25,
            rounds: 20,
            local_epochs: 2,
            lr: 0.1,
            lr_decay: 0.992,
            optimizer: Optimizer::FedAvg,
            wire: WireConfig::default(),
            sharing: Sharing::Full,
            eval_every: 1,
            seed: 42,
            num_threads: 0,
        }
    }
}

/// Experiment scale presets: `tiny` for CI smoke, `small` for the recorded
/// EXPERIMENTS.md numbers, `paper` mirrors the paper's counts (Supp. C.4;
/// not practical on a single CPU core but wired for completeness).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Tiny,
    Small,
    Paper,
}

impl Scale {
    pub fn parse(s: &str) -> Result<Scale, String> {
        match s {
            "tiny" => Ok(Scale::Tiny),
            "small" => Ok(Scale::Small),
            "paper" => Ok(Scale::Paper),
            other => Err(format!("unknown scale '{other}' (tiny|small|paper)")),
        }
    }

    /// (num_clients, samples_per_client, test_samples) for vision runs.
    pub fn vision_population(&self) -> (usize, usize, usize) {
        match self {
            Scale::Tiny => (8, 96, 512),
            Scale::Small => (24, 160, 512),
            Scale::Paper => (100, 500, 10_000),
        }
    }

    /// Default rounds for a "T = 200"-class experiment.
    pub fn rounds(&self, paper_rounds: usize) -> usize {
        match self {
            Scale::Tiny => 8,
            Scale::Small => 30,
            Scale::Paper => paper_rounds,
        }
    }

    /// Sample fraction (paper: 16%).
    pub fn sample_frac(&self) -> f64 {
        match self {
            Scale::Tiny => 0.5,
            Scale::Small => 0.25,
            Scale::Paper => 0.16,
        }
    }

    /// Local epochs E (paper: 10 IID / 5 non-IID for vision).
    pub fn local_epochs(&self) -> usize {
        match self {
            Scale::Tiny => 2,
            Scale::Small => 2,
            Scale::Paper => 10,
        }
    }

    /// Cross-device population presets for the virtual-federation scale
    /// scenario (`fedpara exp scale`): `(population, sample_frac,
    /// samples_per_client)`. `paper` is the classic cross-device regime
    /// (Konečný et al. 2016) FedPara targets: 10⁶ virtual clients at 0.1%
    /// participation. Clients are *virtual* — datasets are synthesized on
    /// demand per round and per-client state is sparse, so even the 10⁶
    /// preset runs in O(participants) memory.
    pub fn cross_device_population(&self) -> (usize, f64, usize) {
        match self {
            Scale::Tiny => (50_000, 0.001, 8),
            Scale::Small => (200_000, 0.0005, 8),
            Scale::Paper => (1_000_000, 0.001, 8),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimizer_parsing() {
        assert_eq!(Optimizer::parse("fedavg").unwrap(), Optimizer::FedAvg);
        assert_eq!(Optimizer::parse("scaffold").unwrap(), Optimizer::Scaffold);
        assert!(matches!(
            Optimizer::parse("fedprox").unwrap(),
            Optimizer::FedProx { .. }
        ));
        assert!(Optimizer::parse("sgd").is_err());
    }

    #[test]
    fn optimizer_hyperparameter_syntax() {
        // Bare names keep the paper defaults...
        assert_eq!(Optimizer::parse("fedprox").unwrap(), Optimizer::FedProx { mu: 0.1 });
        assert_eq!(Optimizer::parse("feddyn").unwrap(), Optimizer::FedDyn { alpha: 0.1 });
        // ...and the colon syntax overrides them.
        assert_eq!(Optimizer::parse("fedprox:0.01").unwrap(), Optimizer::FedProx { mu: 0.01 });
        assert_eq!(Optimizer::parse("feddyn:0.5").unwrap(), Optimizer::FedDyn { alpha: 0.5 });
        assert_eq!(Optimizer::parse("fedprox:0").unwrap(), Optimizer::FedProx { mu: 0.0 });
        // Malformed or misplaced parameters are rejected with context.
        assert!(Optimizer::parse("fedprox:abc").is_err());
        assert!(Optimizer::parse("fedprox:-1").is_err());
        assert!(Optimizer::parse("fedavg:0.1").is_err());
        assert!(Optimizer::parse("scaffold:2").is_err());
    }

    #[test]
    fn optimizer_spec_string_round_trips() {
        for opt in [
            Optimizer::FedAvg,
            Optimizer::FedProx { mu: 0.25 },
            Optimizer::Scaffold,
            Optimizer::FedDyn { alpha: 0.015 },
            Optimizer::FedAdam,
        ] {
            assert_eq!(Optimizer::parse(&opt.spec_string()).unwrap(), opt);
        }
    }

    #[test]
    fn sharing_parsing_round_trips() {
        assert_eq!(Sharing::parse("full").unwrap(), Sharing::Full);
        assert_eq!(Sharing::parse("pfedpara").unwrap(), Sharing::GlobalSegments);
        assert_eq!(Sharing::parse("global-segments").unwrap(), Sharing::GlobalSegments);
        assert_eq!(Sharing::parse("local-only").unwrap(), Sharing::LocalOnly);
        assert_eq!(
            Sharing::parse("fedper:fc2").unwrap(),
            Sharing::FedPer { local_prefixes: vec!["fc2".into()] }
        );
        assert_eq!(
            Sharing::parse("fedper:fc2,conv3").unwrap(),
            Sharing::FedPer { local_prefixes: vec!["fc2".into(), "conv3".into()] }
        );
        assert!(Sharing::parse("fedper").is_err());
        assert!(Sharing::parse("fedper:").is_err());
        assert!(Sharing::parse("bogus").is_err());
        for sh in [
            Sharing::Full,
            Sharing::GlobalSegments,
            Sharing::FedPer { local_prefixes: vec!["fc2".into(), "rnn".into()] },
            Sharing::LocalOnly,
        ] {
            assert_eq!(Sharing::parse(&sh.spec_string()).unwrap(), sh);
        }
    }

    #[test]
    fn scale_parsing_and_presets() {
        assert_eq!(Scale::parse("tiny").unwrap(), Scale::Tiny);
        assert!(Scale::parse("huge").is_err());
        let (k, per, test) = Scale::Small.vision_population();
        assert!(k > 0 && per > 0 && test > 0);
        assert!(Scale::Paper.rounds(200) == 200);
        assert!(Scale::Tiny.rounds(200) < 20);
    }

    #[test]
    fn cross_device_presets_are_cross_device_shaped() {
        // Population ≫ participants at every scale, and the paper preset
        // is the headline 10⁶-clients-at-0.1% regime.
        for s in [Scale::Tiny, Scale::Small, Scale::Paper] {
            let (population, frac, per_client) = s.cross_device_population();
            let participants = (population as f64 * frac).round() as usize;
            assert!(participants >= 1);
            assert!(population >= 1000 * participants, "{s:?} is not cross-device");
            assert!(per_client > 0);
        }
        let (population, frac, _) = Scale::Paper.cross_device_population();
        assert_eq!(population, 1_000_000);
        assert!((frac - 0.001).abs() < 1e-12);
    }

    #[test]
    fn default_config_sane() {
        let c = RunConfig::default();
        assert!(c.sample_frac > 0.0 && c.sample_frac <= 1.0);
        assert!(c.lr > 0.0);
        assert_eq!(c.sharing, Sharing::Full);
        assert_eq!(c.num_threads, 0, "default pool auto-sizes to the host");
        assert_eq!(c.wire, WireConfig::identity(), "default wire is the raw fp32 path");
    }

    #[test]
    fn codec_parsing_round_trips() {
        assert_eq!(CodecSpec::parse("identity").unwrap(), CodecSpec::Identity);
        assert_eq!(CodecSpec::parse("fp16").unwrap(), CodecSpec::Fp16);
        assert_eq!(
            CodecSpec::parse("subsample_quant:0.25").unwrap(),
            CodecSpec::SubsampleQuant { rate: 0.25, levels: 16, feedback: true }
        );
        assert_eq!(
            CodecSpec::parse("subsample_quant:0.1:4").unwrap(),
            CodecSpec::SubsampleQuant { rate: 0.1, levels: 4, feedback: true }
        );
        assert_eq!(
            CodecSpec::parse("subsample_quant:0.1:nofb").unwrap(),
            CodecSpec::SubsampleQuant { rate: 0.1, levels: 16, feedback: false }
        );
        assert_eq!(
            CodecSpec::parse("subsample_quant:0.1:4:nofb").unwrap(),
            CodecSpec::SubsampleQuant { rate: 0.1, levels: 4, feedback: false }
        );
        for spec in [
            CodecSpec::Identity,
            CodecSpec::Fp16,
            CodecSpec::SubsampleQuant { rate: 0.5, levels: 64, feedback: true },
            CodecSpec::SubsampleQuant { rate: 0.5, levels: 64, feedback: false },
        ] {
            assert_eq!(CodecSpec::parse(&spec.spec_string()).unwrap(), spec);
        }
    }

    #[test]
    fn codec_parsing_rejects_bad_specs() {
        assert!(CodecSpec::parse("fp8").is_err());
        assert!(CodecSpec::parse("subsample_quant").is_err());
        assert!(CodecSpec::parse("subsample_quant:abc").is_err());
        assert!(CodecSpec::parse("subsample_quant:0").is_err());
        assert!(CodecSpec::parse("subsample_quant:1.5").is_err());
        assert!(CodecSpec::parse("subsample_quant:0.5:1").is_err());
        assert!(CodecSpec::parse("subsample_quant:0.5:300").is_err());
        assert!(CodecSpec::parse("subsample_quant:0.5:16:bogus").is_err());
        assert!(CodecSpec::parse("subsample_quant:0.5:16:nofb:extra").is_err());
    }

    #[test]
    fn wire_config_direction_constraints() {
        assert!(WireConfig::identity().validate().is_ok());
        assert!(WireConfig::fp16_up().validate().is_ok());
        let both_fp16 = WireConfig {
            up: CodecSpec::Fp16,
            down: CodecSpec::Fp16,
            fingerprint_downloads: true,
        };
        assert!(both_fp16.validate().is_ok());
        let sketch_down = WireConfig {
            up: CodecSpec::Identity,
            down: CodecSpec::SubsampleQuant { rate: 0.5, levels: 16, feedback: true },
            fingerprint_downloads: false,
        };
        assert!(sketch_down.validate().is_err(), "sketch downlink must be rejected");
    }
}
