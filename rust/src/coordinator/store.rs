//! The client store — sparse, lazy state for cross-device populations.
//!
//! FedPara's setting is cross-device federated learning: the server
//! coordinates a population orders of magnitude larger than any round's
//! participant set (Konečný et al. 2016). The seed coordinator
//! materialized a full `ClientState` (dataset + parameter clone) for every
//! client up front, making federation *construction* O(population ×
//! param_count) — tens of GB at 10⁶ clients even for a toy MLP. The
//! `ClientStore` replaces that with two invariants:
//!
//! 1. **Datasets are round-scoped.** A participant's dataset is
//!    materialized deterministically on demand ([`ClientStore::dataset`])
//!    and dropped when its job folds; nothing data-shaped survives the
//!    round. The eager path (caller-provided datasets) still works for
//!    cross-silo runs and is byte-identical.
//! 2. **Persistent state is sparse.** Per-client state (local parameter
//!    segments, SCAFFOLD `c_i`, FedDyn `λ_i`, participation counts) lives
//!    in a sharded hash map keyed by client id, instantiated only for
//!    clients that have participated. A client never touched is
//!    represented *implicitly*: its parameters are exactly the shared
//!    server init (one `Arc`, not a per-client clone), its control/λ are
//!    zeros, its participation count is 0.
//!
//! Together these make round cost O(participants) and live state
//! O(participants + historically-touched) — never O(population). The
//! eager-vs-lazy equivalence suite (`tests/store_equivalence.rs`) pins the
//! store to the eager semantics bit-for-bit; `live_state_bytes` backs the
//! memory-bound assertions in `tests/scale_federation.rs` and the
//! `bench_report` scale section.

use std::collections::HashMap;
use std::sync::Arc;

use crate::data::{partition::Partition, Dataset};
use crate::parameterization::Layout;

use super::client::ClientRecord;

/// Where client datasets come from.
pub enum ClientDataSource {
    /// Pre-materialized per-client datasets (the classic cross-silo path;
    /// population = the vector length).
    Eager(Vec<Arc<Dataset>>),
    /// Virtual population: `provider(cid)` synthesizes client `cid`'s
    /// dataset on demand. The provider must be **deterministic in `cid`**
    /// (same cid → bit-identical dataset, every call) — that is what makes
    /// lazy rounds reproducible and eager/lazy runs equivalent.
    Lazy {
        population: usize,
        provider: Arc<dyn Fn(usize) -> Dataset + Send + Sync>,
    },
}

impl ClientDataSource {
    /// Wrap caller-owned datasets (the classic [`Federation::new`] path).
    ///
    /// [`Federation::new`]: super::server::Federation::new
    pub fn eager(locals: Vec<Dataset>) -> ClientDataSource {
        ClientDataSource::Eager(locals.into_iter().map(Arc::new).collect())
    }

    /// A virtual population served by a deterministic per-client
    /// generator.
    pub fn lazy<F>(population: usize, provider: F) -> ClientDataSource
    where
        F: Fn(usize) -> Dataset + Send + Sync + 'static,
    {
        ClientDataSource::Lazy { population, provider: Arc::new(provider) }
    }

    /// Lazy view over a shared pool + [`Partition`]: client `cid`
    /// materializes `data.subset(partition.client(cid))` on demand. The
    /// pool itself is shared (one `Arc`), so this trades the eager path's
    /// per-client *copies* for one shared pool plus per-round subsets.
    /// Note the provider pins the pool + partition (O(total samples),
    /// caller-shared — not counted by `live_state_bytes`); for true
    /// cross-device populations prefer a synthesizing provider
    /// ([`ClientDataSource::lazy`]), which holds O(1) state.
    pub fn from_partition(data: Arc<Dataset>, part: Arc<Partition>) -> ClientDataSource {
        let population = part.num_clients();
        ClientDataSource::Lazy {
            population,
            provider: Arc::new(move |cid| data.subset(part.client(cid))),
        }
    }

    pub fn population(&self) -> usize {
        match self {
            ClientDataSource::Eager(v) => v.len(),
            ClientDataSource::Lazy { population, .. } => *population,
        }
    }

    /// Heap bytes pinned by the source itself: eager datasets count;
    /// lazy providers count as zero (a synthesizing provider holds O(1)
    /// state, and `from_partition`'s pool is caller-shared).
    fn heap_bytes(&self) -> usize {
        match self {
            ClientDataSource::Eager(v) => v.iter().map(|d| d.heap_bytes()).sum(),
            ClientDataSource::Lazy { .. } => 0,
        }
    }
}

/// One participant's dataset handle for one round: either an eager
/// shared dataset, or a deferred synthesis token the worker materializes
/// itself (see [`ClientStore::round_data`]).
pub enum RoundData {
    Shared(Arc<Dataset>),
    Deferred {
        cid: usize,
        provider: Arc<dyn Fn(usize) -> Dataset + Send + Sync>,
    },
}

impl RoundData {
    /// Resolve to a concrete dataset (synthesizing on the calling thread
    /// when deferred).
    pub fn materialize(self) -> Arc<Dataset> {
        match self {
            RoundData::Shared(d) => d,
            RoundData::Deferred { cid, provider } => Arc::new(provider(cid)),
        }
    }
}

/// How a touched client's parameters persist between participations —
/// derived from the effective layout + sharing, never configured directly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParamPolicy {
    /// Every segment is overwritten by the next download (full sharing):
    /// nothing persists; a client's round parameters are always
    /// `scatter_global(init, current_global)`.
    Dropped,
    /// Partial sharing: only the dense local-segment vector persists
    /// (`Layout::gather_local` encoding).
    LocalSegments,
    /// Local-only training: the full parameter vector persists (nothing is
    /// ever transferred).
    FullVector,
}

/// Shard count for the sparse map: bounds any single rehash and keeps the
/// per-shard maps small enough that iteration in `live_state_bytes` stays
/// cache-friendly. Power of two so the index is a mask.
const STORE_SHARDS: usize = 64;

/// Sparse, lazy client state for one federation. See the module docs.
pub struct ClientStore {
    source: ClientDataSource,
    /// Effective transfer layout (sharing policy applied).
    layout: Arc<Layout>,
    policy: ParamPolicy,
    /// The common init every client starts from (Algorithm 2's "transmit
    /// everything at start") — shared, not cloned per client.
    init_params: Arc<Vec<f32>>,
    /// Fingerprint of the init *global* broadcast (set only when the run
    /// fingerprints downloads): the hash every untouched client's holdings
    /// implicitly carry, since untouched clients are exactly the shared
    /// init.
    init_global_hash: Option<[u8; 32]>,
    shards: Vec<HashMap<usize, ClientRecord>>,
    touched: usize,
}

impl ClientStore {
    /// `local_only` marks the no-transfer sharing mode (downloads never
    /// happen, so the full vector must persist regardless of layout).
    pub fn new(
        source: ClientDataSource,
        layout: Arc<Layout>,
        init_params: Arc<Vec<f32>>,
        local_only: bool,
    ) -> ClientStore {
        assert_eq!(init_params.len(), layout.total, "init/layout mismatch");
        let policy = if local_only {
            ParamPolicy::FullVector
        } else if layout.local_len() == 0 {
            ParamPolicy::Dropped
        } else {
            ParamPolicy::LocalSegments
        };
        ClientStore {
            source,
            layout,
            policy,
            init_params,
            init_global_hash: None,
            shards: (0..STORE_SHARDS).map(|_| HashMap::new()).collect(),
            touched: 0,
        }
    }

    /// Prime the fingerprint cache with the init broadcast's hash — the
    /// wire global every client implicitly holds before its first
    /// download. Set once at federation construction when the run
    /// fingerprints downloads.
    pub fn set_init_global_hash(&mut self, hash: [u8; 32]) {
        self.init_global_hash = Some(hash);
    }

    pub fn population(&self) -> usize {
        self.source.population()
    }

    pub fn policy(&self) -> ParamPolicy {
        self.policy
    }

    /// Is this a virtual (lazily synthesized) population?
    pub fn is_virtual(&self) -> bool {
        matches!(self.source, ClientDataSource::Lazy { .. })
    }

    /// Clients with any instantiated state (the "historically touched"
    /// set the memory bound is phrased in).
    pub fn touched(&self) -> usize {
        self.touched
    }

    /// Client `cid`'s dataset for this round, materialized immediately.
    /// Eager: a shared handle. Lazy: synthesized now, owned by the
    /// caller, dropped when the caller is done — the store keeps nothing.
    pub fn dataset(&self, cid: usize) -> Arc<Dataset> {
        self.round_data(cid).materialize()
    }

    /// Client `cid`'s dataset handle for one round. For lazy sources the
    /// synthesis is **deferred**: the handle carries the provider, and
    /// the worker thread running the job materializes it — keeping the
    /// O(per_client) generation work off the coordinator thread (the
    /// provider is deterministic in `cid`, so where it runs cannot change
    /// results).
    pub fn round_data(&self, cid: usize) -> RoundData {
        assert!(cid < self.population(), "client {cid} out of population");
        match &self.source {
            ClientDataSource::Eager(v) => RoundData::Shared(Arc::clone(&v[cid])),
            ClientDataSource::Lazy { provider, .. } => {
                RoundData::Deferred { cid, provider: Arc::clone(provider) }
            }
        }
    }

    #[inline]
    fn shard_of(cid: usize) -> usize {
        cid & (STORE_SHARDS - 1)
    }

    fn record(&self, cid: usize) -> Option<&ClientRecord> {
        self.shards[Self::shard_of(cid)].get(&cid)
    }

    fn record_mut(&mut self, cid: usize) -> &mut ClientRecord {
        assert!(cid < self.population(), "client {cid} out of population");
        let touched = &mut self.touched;
        self.shards[Self::shard_of(cid)].entry(cid).or_insert_with(|| {
            *touched += 1;
            ClientRecord::default()
        })
    }

    /// The full parameter vector client `cid` enters a round with (before
    /// any download) — exactly what the eager path stored per client:
    /// the shared init overlaid with whatever this client persisted.
    pub fn round_params(&self, cid: usize) -> Vec<f32> {
        assert!(cid < self.population(), "client {cid} out of population");
        let stored = self.record(cid).and_then(|r| r.params.as_ref());
        match (self.policy, stored) {
            (ParamPolicy::FullVector, Some(full)) => full.clone(),
            (ParamPolicy::LocalSegments, Some(local)) => {
                let mut p = self.init_params.as_ref().clone();
                self.layout.scatter_local(&mut p, local);
                p
            }
            // Untouched (or Dropped-policy) clients are implicitly the
            // shared init — the "round-trips as exactly the server
            // global" invariant.
            _ => self.init_params.as_ref().clone(),
        }
    }

    /// SCAFFOLD control variate c_i (zeros until the client first
    /// uploads one). Does not instantiate a record.
    pub fn control(&self, cid: usize, dim: usize) -> Vec<f32> {
        match self.record(cid).and_then(|r| r.control.as_ref()) {
            Some(c) => c.clone(),
            None => vec![0.0; dim],
        }
    }

    /// FedDyn λ_i (zeros until first update). Does not instantiate a
    /// record.
    pub fn lambda(&self, cid: usize, dim: usize) -> Vec<f32> {
        match self.record(cid).and_then(|r| r.lambda.as_ref()) {
            Some(l) => l.clone(),
            None => vec![0.0; dim],
        }
    }

    pub fn participations(&self, cid: usize) -> u32 {
        self.record(cid).map(|r| r.participations).unwrap_or(0)
    }

    /// True while an async upload from `cid` is buffered server-side.
    pub fn in_flight(&self, cid: usize) -> bool {
        self.record(cid).map(|r| r.in_flight).unwrap_or(false)
    }

    pub fn set_in_flight(&mut self, cid: usize, in_flight: bool) {
        self.record_mut(cid).in_flight = in_flight;
    }

    /// Server model version `cid` last trained against (0 = never).
    pub fn last_version(&self, cid: usize) -> u64 {
        self.record(cid).map(|r| r.last_version).unwrap_or(0)
    }

    pub fn set_last_version(&mut self, cid: usize, version: u64) {
        self.record_mut(cid).last_version = version;
    }

    /// Uplink error-feedback accumulator for client `cid` (empty until the
    /// client first transmits through a feedback codec — the codec treats
    /// an empty accumulator as zeros). Does not instantiate a record.
    pub fn feedback(&self, cid: usize) -> Vec<f32> {
        self.record(cid)
            .and_then(|r| r.feedback.as_ref())
            .cloned()
            .unwrap_or_default()
    }

    /// Hash of the last wire global client `cid` received — falling back
    /// to the init broadcast's hash for clients never explicitly
    /// delivered to (they hold the shared init by construction). `None`
    /// when the run doesn't fingerprint downloads.
    pub fn last_global_hash(&self, cid: usize) -> Option<[u8; 32]> {
        self.record(cid)
            .and_then(|r| r.last_global)
            .or(self.init_global_hash)
    }

    /// Commit one participant's post-round state. `params` is the
    /// client's full post-training vector; the policy decides what (if
    /// anything) of it persists. `received` is the fingerprint of the
    /// wire global this round delivered (recorded whether or not the
    /// delivery was billed — a cache hit means the client already held
    /// those exact bits).
    pub fn commit(
        &mut self,
        cid: usize,
        params: Vec<f32>,
        control: Option<Vec<f32>>,
        lambda: Option<Vec<f32>>,
        feedback: Option<Vec<f32>>,
        received: Option<[u8; 32]>,
    ) {
        let policy = self.policy;
        let layout = Arc::clone(&self.layout);
        let rec = self.record_mut(cid);
        rec.participations += 1;
        match policy {
            ParamPolicy::Dropped => {}
            ParamPolicy::LocalSegments => rec.params = Some(layout.gather_local(&params)),
            ParamPolicy::FullVector => rec.params = Some(params),
        }
        if let Some(c) = control {
            rec.control = Some(c);
        }
        if let Some(l) = lambda {
            rec.lambda = Some(l);
        }
        if let Some(f) = feedback {
            rec.feedback = Some(f);
        }
        if let Some(h) = received {
            rec.last_global = Some(h);
        }
    }

    /// Bytes of live per-client state held right now: the shared init, the
    /// sparse records (+ a conservative per-entry map overhead), and — in
    /// eager mode — the caller's datasets. The scale suite asserts this is
    /// O(participants + touched), independent of population.
    pub fn live_state_bytes(&self) -> usize {
        // Map entry ≈ key + record struct + bucket slot; 2× the payload
        // size is a deliberate overestimate so the asserted bound is
        // honest about allocator slack.
        const ENTRY_OVERHEAD: usize =
            2 * (std::mem::size_of::<usize>() + std::mem::size_of::<ClientRecord>());
        let records: usize = self
            .shards
            .iter()
            .flat_map(|s| s.values())
            .map(|r| r.heap_bytes() + ENTRY_OVERHEAD)
            .sum();
        self.init_params.len() * 4 + records + self.source.heap_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parameterization::{Segment, SegmentKind};

    fn split_layout() -> Arc<Layout> {
        Arc::new(
            Layout::new(vec![
                Segment { name: "g".into(), offset: 0, len: 4, kind: SegmentKind::Global, init_std: 0.0 },
                Segment { name: "l".into(), offset: 4, len: 3, kind: SegmentKind::Local, init_std: 0.0 },
            ])
            .unwrap(),
        )
    }

    fn lazy_store(population: usize, layout: Arc<Layout>, local_only: bool) -> ClientStore {
        let init = Arc::new((0..layout.total).map(|i| i as f32).collect::<Vec<_>>());
        let source = ClientDataSource::lazy(population, |cid| Dataset {
            features: vec![cid as f32; 2],
            labels: vec![0, 1],
            feature_dim: 1,
            num_classes: 2,
        });
        ClientStore::new(source, layout, init, local_only)
    }

    #[test]
    fn untouched_clients_are_implicit_init() {
        let store = lazy_store(1_000_000, split_layout(), false);
        assert_eq!(store.population(), 1_000_000);
        assert_eq!(store.touched(), 0);
        assert_eq!(store.round_params(999_999), (0..7).map(|i| i as f32).collect::<Vec<_>>());
        assert_eq!(store.control(5, 7), vec![0.0; 7]);
        assert_eq!(store.lambda(5, 7), vec![0.0; 7]);
        assert_eq!(store.participations(5), 0);
        // Reads never instantiate state.
        assert_eq!(store.touched(), 0);
    }

    #[test]
    fn lazy_datasets_are_deterministic_and_round_scoped() {
        let store = lazy_store(100, split_layout(), false);
        let a = store.dataset(7);
        let b = store.dataset(7);
        assert_eq!(a.features, b.features);
        assert_eq!(Arc::strong_count(&a), 1, "lazy datasets are caller-owned");
        assert_ne!(store.dataset(8).features, a.features);
    }

    #[test]
    fn commit_persists_only_local_segments_under_partial_sharing() {
        let mut store = lazy_store(100, split_layout(), false);
        assert_eq!(store.policy(), ParamPolicy::LocalSegments);
        let trained: Vec<f32> = (0..7).map(|i| 100.0 + i as f32).collect();
        store.commit(3, trained, None, None, None, None);
        assert_eq!(store.touched(), 1);
        assert_eq!(store.participations(3), 1);
        // Round params = init overlaid with the persisted local segment.
        let p = store.round_params(3);
        assert_eq!(&p[..4], &[0.0, 1.0, 2.0, 3.0], "global half stays at init");
        assert_eq!(&p[4..], &[104.0, 105.0, 106.0], "local half persisted");
    }

    #[test]
    fn full_sharing_drops_params_but_counts_participation() {
        let all_global = Arc::new(Layout::single(7));
        let init = Arc::new(vec![1.5f32; 7]);
        let mut store = ClientStore::new(
            ClientDataSource::lazy(1000, |_| Dataset {
                features: vec![0.0],
                labels: vec![0],
                feature_dim: 1,
                num_classes: 2,
            }),
            all_global,
            init,
            false,
        );
        assert_eq!(store.policy(), ParamPolicy::Dropped);
        let before = store.live_state_bytes();
        store.commit(9, vec![9.0; 7], None, None, None, None);
        assert_eq!(store.participations(9), 1);
        assert_eq!(store.round_params(9), vec![1.5; 7], "params dropped under full sharing");
        // A dropped-policy commit adds only the map entry, no vectors.
        // (The bound is 2× the entry struct + key; the record carries a
        // handful of inline Options — wire feedback, last-global hash —
        // but still no heap.)
        assert!(store.live_state_bytes() - before < 512);
    }

    #[test]
    fn local_only_persists_full_vector() {
        let mut store = lazy_store(100, split_layout(), true);
        assert_eq!(store.policy(), ParamPolicy::FullVector);
        store.commit(2, vec![7.0; 7], None, None, None, None);
        assert_eq!(store.round_params(2), vec![7.0; 7]);
    }

    #[test]
    fn live_state_is_population_independent() {
        let small = lazy_store(1_000, split_layout(), false);
        let huge = lazy_store(1_000_000, split_layout(), false);
        assert_eq!(small.live_state_bytes(), huge.live_state_bytes());
        let mut huge = huge;
        for cid in 0..10 {
            huge.commit(cid * 31, vec![0.0; 7], Some(vec![0.0; 7]), None, None, None);
        }
        assert_eq!(huge.touched(), 10);
        // 10 records of a 7-dim model: comfortably under a kilobyte each.
        assert!(huge.live_state_bytes() < small.live_state_bytes() + 10 * 1024);
    }

    #[test]
    fn feedback_defaults_empty_and_persists_on_commit() {
        let mut store = lazy_store(100, split_layout(), false);
        assert!(store.feedback(42).is_empty(), "no accumulator before first transmit");
        assert_eq!(store.touched(), 0, "feedback reads never instantiate state");
        store.commit(42, vec![0.0; 7], None, None, Some(vec![0.5, -0.5, 0.25]), None);
        assert_eq!(store.feedback(42), vec![0.5, -0.5, 0.25]);
        // A later commit without feedback (e.g. after switching codecs in
        // a resumed run) leaves the accumulator as-is.
        store.commit(42, vec![0.0; 7], None, None, None, None);
        assert_eq!(store.feedback(42), vec![0.5, -0.5, 0.25]);
    }

    #[test]
    fn last_global_hash_falls_back_to_init_broadcast() {
        let mut store = lazy_store(100, split_layout(), false);
        // No fingerprinting configured: nothing to compare against.
        assert_eq!(store.last_global_hash(7), None);
        let init_h = [1u8; 32];
        store.set_init_global_hash(init_h);
        // Untouched clients implicitly hold the init broadcast.
        assert_eq!(store.last_global_hash(7), Some(init_h));
        let round_h = [2u8; 32];
        store.commit(7, vec![0.0; 7], None, None, None, Some(round_h));
        assert_eq!(store.last_global_hash(7), Some(round_h));
        // Other clients still fall back to the init hash.
        assert_eq!(store.last_global_hash(8), Some(init_h));
    }

    #[test]
    fn from_partition_matches_eager_subsets() {
        let data = Arc::new(Dataset {
            features: (0..20).map(|i| i as f32).collect(),
            labels: (0..10).map(|i| (i % 2) as u32).collect(),
            feature_dim: 2,
            num_classes: 2,
        });
        let part = Arc::new(Partition { clients: vec![vec![0, 2, 4], vec![1, 3], vec![5, 6, 7, 8, 9]] });
        let src = ClientDataSource::from_partition(Arc::clone(&data), Arc::clone(&part));
        assert_eq!(src.population(), 3);
        let store = ClientStore::new(src, Arc::new(Layout::single(1)), Arc::new(vec![0.0]), false);
        for cid in 0..3 {
            let lazy = store.dataset(cid);
            let eager = data.subset(part.client(cid));
            assert_eq!(lazy.features, eager.features);
            assert_eq!(lazy.labels, eager.labels);
        }
    }
}
