//! Communication accounting: bytes, energy, simulated wall-clock.
//!
//! The paper's cost metric is total transferred bits,
//! `2 × (#participants) × (model size) × (#rounds)` (§3.2), i.e. both
//! up- and down-link are counted. Energy follows the user-to-data-center
//! topology model of Yan et al. (2019) — a per-byte constant — and the
//! wall-clock simulation (Supp. D.1) uses
//! `t = t_comp + 2 · model_bytes / network_speed` with homogeneous link
//! quality across clients.

/// Joules per transferred byte (Yan et al. 2019-style access+core network
/// energy intensity, ≈0.31 µJ/bit). Only scales the energy axis; the
/// paper's comparisons are ratios.
pub const ENERGY_J_PER_BYTE: f64 = 2.5e-6;

/// Per-job communication record: one client's traffic for one round.
///
/// Local-training jobs run on the worker pool and cannot touch the shared
/// [`CommLedger`]; each job accumulates its own delta and the round loop
/// merges them into the ledger **in participant order**, so ledger contents
/// are byte-identical to a sequential round regardless of pool size.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CommDelta {
    pub up_bytes: u64,
    pub down_bytes: u64,
}

impl CommDelta {
    pub fn record_upload(&mut self, bytes: u64) {
        self.up_bytes = self.up_bytes.saturating_add(bytes);
    }

    pub fn record_download(&mut self, bytes: u64) {
        self.down_bytes = self.down_bytes.saturating_add(bytes);
    }
}

/// Running ledger of transferred bytes.
#[derive(Clone, Debug, Default)]
pub struct CommLedger {
    pub up_bytes: u64,
    pub down_bytes: u64,
    /// Per-round history of (up, down) for curves like Figure 3.
    pub per_round: Vec<(u64, u64)>,
    round_up: u64,
    round_down: u64,
}

impl CommLedger {
    pub fn new() -> CommLedger {
        CommLedger::default()
    }

    pub fn record_upload(&mut self, bytes: u64) {
        self.up_bytes = self.up_bytes.saturating_add(bytes);
        self.round_up = self.round_up.saturating_add(bytes);
    }

    pub fn record_download(&mut self, bytes: u64) {
        self.down_bytes = self.down_bytes.saturating_add(bytes);
        self.round_down = self.round_down.saturating_add(bytes);
    }

    /// Merge one client job's traffic into the current round.
    pub fn apply(&mut self, delta: CommDelta) {
        self.record_upload(delta.up_bytes);
        self.record_download(delta.down_bytes);
    }

    /// Close out the current round's accounting.
    pub fn end_round(&mut self) {
        self.per_round.push((self.round_up, self.round_down));
        self.round_up = 0;
        self.round_down = 0;
    }

    /// Per-direction byte split for one finished round: `(up, down)`.
    /// This is what makes a compression claim auditable per rung — a
    /// downlink codec must move `down` and leave `up` alone, and vice
    /// versa.
    pub fn round_split(&self, round: usize) -> Option<(u64, u64)> {
        self.per_round.get(round).copied()
    }

    /// Total transferred bytes. Saturating like the recorders: at
    /// cross-device scale (10⁶ clients × GB-class models × 10⁵ rounds) a
    /// mis-specified scenario can legitimately approach u64::MAX, and a
    /// pinned ceiling beats a silent wrap (release) or panic (debug) in
    /// the middle of a long simulation.
    pub fn total_bytes(&self) -> u64 {
        self.up_bytes.saturating_add(self.down_bytes)
    }

    pub fn total_gbytes(&self) -> f64 {
        self.total_bytes() as f64 / 1e9
    }

    /// Energy consumed by all transfers (Joules).
    pub fn total_energy_j(&self) -> f64 {
        self.total_bytes() as f64 * ENERGY_J_PER_BYTE
    }

    pub fn total_energy_mj(&self) -> f64 {
        self.total_energy_j() / 1e6
    }
}

/// Simulated network for the Supp. D.1 wall-clock tables.
///
/// Real cross-device links are asymmetric (uplink is typically the scarce
/// direction), so the two directions carry independent rates. The paper's
/// tables use symmetric 2/10/50 Mbps links — [`Network::new`] keeps that
/// form and is exactly `asymmetric(mbps, mbps)`.
#[derive(Clone, Copy, Debug)]
pub struct Network {
    /// Client→server link speed in megabits per second.
    pub up_mbps: f64,
    /// Server→client link speed in megabits per second.
    pub down_mbps: f64,
}

impl Network {
    /// Symmetric link (the paper's 2/10/50 Mbps settings).
    pub fn new(mbps: f64) -> Network {
        Network::asymmetric(mbps, mbps)
    }

    pub fn asymmetric(up_mbps: f64, down_mbps: f64) -> Network {
        assert!(up_mbps > 0.0 && down_mbps > 0.0);
        Network { up_mbps, down_mbps }
    }

    /// Seconds to upload `bytes` (client→server).
    pub fn up_secs(&self, bytes: u64) -> f64 {
        (bytes as f64 * 8.0) / (self.up_mbps * 1e6)
    }

    /// Seconds to download `bytes` (server→client).
    pub fn down_secs(&self, bytes: u64) -> f64 {
        (bytes as f64 * 8.0) / (self.down_mbps * 1e6)
    }

    /// Per-round communication time for one client: download + upload of
    /// `model_bytes` (the paper's `2·size/speed` on symmetric links).
    pub fn round_comm_secs(&self, model_bytes: u64) -> f64 {
        self.round_comm_secs_split(model_bytes, model_bytes)
    }

    /// Per-round communication time with direction-specific byte counts —
    /// the form wire codecs need, since up/down payloads differ per rung.
    pub fn round_comm_secs_split(&self, up_bytes: u64, down_bytes: u64) -> f64 {
        self.up_secs(up_bytes) + self.down_secs(down_bytes)
    }
}

/// Quantize an upload through fp16 (FedPAQ-style, Supp. D.3): returns the
/// dequantized values the server will see and the bytes on the wire.
///
/// The round loop now routes through `coordinator::wire::Fp16`, which is
/// pinned bit-identical to this pair; these helpers remain the reference
/// implementation that pin holds against.
pub fn quantize_fp16(values: &[f32]) -> (Vec<f32>, u64) {
    let deq = crate::util::f16::quantize_roundtrip(values);
    (deq, (values.len() * 2) as u64)
}

/// In-place [`quantize_fp16`]: overwrites `values` with what the server
/// will see after the fp16 wire roundtrip and returns the bytes on the
/// wire. The round loop's upload path uses this form so quantization adds
/// no allocation per client per round.
pub fn quantize_fp16_in_place(values: &mut [f32]) -> u64 {
    crate::util::f16::quantize_roundtrip_in_place(values);
    (values.len() * 2) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_accumulates_and_rounds() {
        let mut l = CommLedger::new();
        l.record_download(100);
        l.record_upload(50);
        l.end_round();
        l.record_download(100);
        l.end_round();
        assert_eq!(l.total_bytes(), 250);
        assert_eq!(l.per_round, vec![(50, 100), (0, 100)]);
    }

    #[test]
    fn delta_merge_matches_direct_recording() {
        // Recording through per-job deltas must equal direct recording.
        let mut direct = CommLedger::new();
        direct.record_download(100);
        direct.record_upload(40);
        direct.record_download(200);
        direct.record_upload(80);
        direct.end_round();

        let mut merged = CommLedger::new();
        for (down, up) in [(100, 40), (200, 80)] {
            let mut d = CommDelta::default();
            d.record_download(down);
            d.record_upload(up);
            merged.apply(d);
        }
        merged.end_round();
        assert_eq!(direct.per_round, merged.per_round);
        assert_eq!(direct.total_bytes(), merged.total_bytes());
    }

    #[test]
    fn paper_cost_formula() {
        // 2 × participants × model_size × rounds.
        let mut l = CommLedger::new();
        let participants = 16u64;
        let model_bytes = 1000u64;
        let rounds = 5;
        for _ in 0..rounds {
            for _ in 0..participants {
                l.record_download(model_bytes);
                l.record_upload(model_bytes);
            }
            l.end_round();
        }
        assert_eq!(l.total_bytes(), 2 * participants * model_bytes * rounds);
    }

    #[test]
    fn network_times_match_supp_table7() {
        // VGG16 (15.25M params ≈ 58.2 MB at f32): paper reports
        // t_comm = 470.2 s at 2 Mbps for up+down.
        let vgg16_bytes = 15_250_000u64 * 4;
        let net = Network::new(2.0);
        let t = net.round_comm_secs(vgg16_bytes);
        assert!(
            (t - 470.2).abs() < 30.0,
            "2 Mbps round time {t:.1}s should be ≈470s like the paper"
        );
        // 50 Mbps → ≈18.6 s.
        let t50 = Network::new(50.0).round_comm_secs(vgg16_bytes);
        assert!((t50 - 18.61).abs() < 1.5, "50 Mbps time {t50:.2}");
    }

    #[test]
    fn asymmetric_network_splits_directions() {
        // A 5 Mbps up / 20 Mbps down link: 1 MB takes 1.6 s up, 0.4 s down.
        let net = Network::asymmetric(5.0, 20.0);
        assert!((net.up_secs(1_000_000) - 1.6).abs() < 1e-12);
        assert!((net.down_secs(1_000_000) - 0.4).abs() < 1e-12);
        assert!((net.round_comm_secs(1_000_000) - 2.0).abs() < 1e-12);
        // Direction-specific byte counts (fp16 downlink halves only down).
        let t = net.round_comm_secs_split(1_000_000, 500_000);
        assert!((t - (1.6 + 0.2)).abs() < 1e-12);
        // The symmetric constructor is exactly the asymmetric one folded.
        let sym = Network::new(10.0);
        assert_eq!(sym.up_mbps, sym.down_mbps);
        assert!((sym.round_comm_secs(1_000_000) - Network::asymmetric(10.0, 10.0).round_comm_secs(1_000_000)).abs() < 1e-15);
    }

    #[test]
    fn ledger_round_split_is_per_direction() {
        let mut l = CommLedger::new();
        l.record_upload(11);
        l.record_download(22);
        l.end_round();
        l.record_download(5);
        l.end_round();
        assert_eq!(l.round_split(0), Some((11, 22)));
        assert_eq!(l.round_split(1), Some((0, 5)));
        assert_eq!(l.round_split(2), None);
    }

    #[test]
    fn energy_scales_with_bytes() {
        let mut l = CommLedger::new();
        l.record_upload(1_000_000_000);
        assert!((l.total_energy_j() - 2500.0).abs() < 1e-6);
    }

    // -- population-scale coverage -------------------------------------

    #[test]
    fn ledger_saturates_instead_of_wrapping_near_u64_max() {
        let mut l = CommLedger::new();
        l.record_upload(u64::MAX - 10);
        l.record_upload(100); // Would wrap; must pin at MAX.
        assert_eq!(l.up_bytes, u64::MAX);
        l.record_download(u64::MAX / 2 + 10);
        l.record_download(u64::MAX / 2 + 10);
        assert_eq!(l.down_bytes, u64::MAX);
        // total = up + down would overflow twice over; stays pinned.
        assert_eq!(l.total_bytes(), u64::MAX);
        l.end_round();
        assert_eq!(l.per_round, vec![(u64::MAX, u64::MAX)]);

        // Deltas saturate the same way before they ever reach the ledger.
        let mut d = CommDelta::default();
        d.record_upload(u64::MAX);
        d.record_upload(1);
        assert_eq!(d.up_bytes, u64::MAX);
        let mut merged = CommLedger::new();
        merged.apply(d);
        merged.apply(CommDelta { up_bytes: 5, down_bytes: 0 });
        assert_eq!(merged.up_bytes, u64::MAX);
    }

    #[test]
    fn fp16_billing_on_odd_length_uploads() {
        // fp16 is exactly 2 bytes/value with no padding assumption: odd
        // (and prime) lengths must bill exactly 2·len both in the
        // allocating and the in-place form.
        for len in [1usize, 3, 7, 101, 999, 65_537] {
            let vals: Vec<f32> = (0..len).map(|i| (i as f32) * 0.25 - 2.0).collect();
            let (deq, bytes) = quantize_fp16(&vals);
            assert_eq!(bytes, 2 * len as u64, "len {len}");
            assert_eq!(deq.len(), len);
            let mut inplace = vals.clone();
            assert_eq!(quantize_fp16_in_place(&mut inplace), bytes);
            assert_eq!(inplace, deq);
        }
    }

    #[test]
    fn accounting_is_exact_over_1e5_rounds() {
        // 10⁵ simulated rounds of a 1000-participant federation: the u64
        // byte ledger is exact (integer), and the f64 energy /
        // transfer-time aggregates stay within float accumulation error
        // of the closed form.
        let rounds: u64 = 100_000;
        let per_round_up: u64 = 1000 * 25_000; // 1000 clients × 25 kB up
        let per_round_down: u64 = 1000 * 50_000;
        let mut l = CommLedger::new();
        let net = Network::new(10.0);
        let mut t_secs = 0.0f64;
        for _ in 0..rounds {
            l.record_upload(per_round_up);
            l.record_download(per_round_down);
            l.end_round();
            t_secs += net.round_comm_secs_split(per_round_up, per_round_down);
        }
        assert_eq!(l.up_bytes, rounds * per_round_up);
        assert_eq!(l.down_bytes, rounds * per_round_down);
        assert_eq!(l.per_round.len(), rounds as usize);
        assert_eq!(l.per_round[77_777], (per_round_up, per_round_down));
        let expected_t = rounds as f64 * net.round_comm_secs_split(per_round_up, per_round_down);
        assert!(
            (t_secs - expected_t).abs() / expected_t < 1e-9,
            "transfer-time accumulation drifted: {t_secs} vs {expected_t}"
        );
        let expected_j = (rounds * (per_round_up + per_round_down)) as f64 * ENERGY_J_PER_BYTE;
        assert!(
            (l.total_energy_j() - expected_j).abs() / expected_j < 1e-12,
            "energy drifted: {} vs {expected_j}",
            l.total_energy_j()
        );
    }

    #[test]
    fn fp16_quantization_halves_bytes() {
        let vals: Vec<f32> = (0..1000).map(|i| i as f32 * 0.01 - 5.0).collect();
        let (deq, bytes) = quantize_fp16(&vals);
        assert_eq!(bytes, 2000);
        assert_eq!(deq.len(), vals.len());
        // Quantization error bounded for in-range values.
        for (a, b) in vals.iter().zip(deq.iter()) {
            assert!((a - b).abs() <= a.abs() / 1024.0 + 1e-4);
        }
        // The allocation-free form sees the same wire values and bytes.
        let mut inplace = vals.clone();
        let bytes2 = quantize_fp16_in_place(&mut inplace);
        assert_eq!(bytes2, bytes);
        assert_eq!(inplace, deq);
    }
}
