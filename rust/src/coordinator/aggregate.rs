//! Server-side aggregation primitives.
//!
//! * [`weighted_mean`] — FedAvg's sample-count-weighted model average.
//! * [`AdamState`] — FedAdam (Reddi et al. 2021): server-side Adam over the
//!   average client delta.
//! * [`ScaffoldState`] — SCAFFOLD (Karimireddy et al. 2020) server control
//!   variate and global-lr update.
//! * [`FedDynState`] — FedDyn (Acar et al. 2021) server `h` state.
//!
//! All operate on flat f32 vectors (the transfer representation), so they
//! compose with the pFedPara global/local split transparently.

/// Streaming sample-count-weighted mean accumulator.
///
/// The round loop folds each client upload into this as it arrives (in
/// participant order) and drops the upload immediately, so aggregation
/// itself holds `O(dim)` state instead of the `O(participants × dim)` the
/// old materialize-all-uploads path needed (the ordered fold can still
/// buffer out-of-order job results upstream — see `ThreadPool::scope_fold`).
/// Accumulation is f64 for the same numerics as the batch
/// [`weighted_mean`].
#[derive(Clone, Debug)]
pub struct WeightedAccumulator {
    sum: Vec<f64>,
    total_weight: f64,
    count: usize,
    /// Per-coordinate weight totals, allocated lazily by the first
    /// [`WeightedAccumulator::push_masked`] call. `None` means every push
    /// so far covered all coordinates (the homogeneous fast path — zero
    /// extra state, arithmetic untouched).
    coord_weight: Option<Vec<f64>>,
}

impl WeightedAccumulator {
    pub fn new(dim: usize) -> WeightedAccumulator {
        WeightedAccumulator { sum: vec![0.0; dim], total_weight: 0.0, count: 0, coord_weight: None }
    }

    /// Fold one vector in with weight `w` (> 0).
    pub fn push(&mut self, v: &[f32], w: f64) {
        assert_eq!(v.len(), self.sum.len(), "inconsistent vector lengths");
        assert!(w > 0.0, "non-positive weight");
        for (o, &x) in self.sum.iter_mut().zip(v.iter()) {
            *o += w * x as f64;
        }
        if let Some(cw) = &mut self.coord_weight {
            for c in cw.iter_mut() {
                *c += w;
            }
        }
        self.total_weight += w;
        self.count += 1;
    }

    /// Fold one vector in with weight `w`, counting only the coordinates
    /// where `active[i]` is true — the factor-space aggregation path for
    /// rank-truncated clients: a small device contributes nothing (neither
    /// value nor weight) at coordinates outside its rank budget, so
    /// coordinates seen by fewer clients are renormalized by their own
    /// weight total instead of being systematically shrunk toward zero.
    pub fn push_masked(&mut self, v: &[f32], w: f64, active: &[bool]) {
        assert_eq!(v.len(), self.sum.len(), "inconsistent vector lengths");
        assert_eq!(active.len(), self.sum.len(), "inconsistent mask length");
        assert!(w > 0.0, "non-positive weight");
        // Every earlier full push weighted all coordinates equally.
        let prior = self.total_weight;
        let cw = self.coord_weight.get_or_insert_with(|| vec![prior; v.len()]);
        for i in 0..v.len() {
            if active[i] {
                self.sum[i] += w * v[i] as f64;
                cw[i] += w;
            }
        }
        self.total_weight += w;
        self.count += 1;
    }

    pub fn count(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// The weighted mean of everything pushed so far.
    pub fn mean(&self) -> Vec<f32> {
        assert!(self.count > 0, "no vectors to aggregate");
        assert!(self.total_weight > 0.0, "weights sum to zero");
        let inv = 1.0 / self.total_weight;
        self.sum.iter().map(|&x| (x * inv) as f32).collect()
    }

    /// [`WeightedAccumulator::mean`] with per-coordinate renormalization:
    /// each coordinate divides by the weight that actually covered it, and
    /// a coordinate no push covered falls back to `fallback` (the server's
    /// previous global — the model holds where nobody trained). With no
    /// masked pushes this delegates to [`WeightedAccumulator::mean`]
    /// bit-for-bit, so the homogeneous default is pinned unchanged.
    pub fn mean_or(&self, fallback: &[f32]) -> Vec<f32> {
        assert_eq!(fallback.len(), self.sum.len(), "inconsistent fallback length");
        let Some(cw) = &self.coord_weight else {
            return self.mean();
        };
        assert!(self.count > 0, "no vectors to aggregate");
        self.sum
            .iter()
            .zip(cw)
            .zip(fallback)
            .map(|((&s, &w), &f)| if w > 0.0 { (s / w) as f32 } else { f })
            .collect()
    }
}

/// Sample-count-weighted mean of client vectors. All vectors must share a
/// length; weights must be positive. (Batch convenience over
/// [`WeightedAccumulator`]; the round loop streams instead.)
pub fn weighted_mean(vectors: &[Vec<f32>], weights: &[f64]) -> Vec<f32> {
    assert_eq!(vectors.len(), weights.len());
    assert!(!vectors.is_empty(), "no vectors to aggregate");
    let mut acc = WeightedAccumulator::new(vectors[0].len());
    for (v, &w) in vectors.iter().zip(weights) {
        acc.push(v, w);
    }
    acc.mean()
}

/// In-place `a += s · b`.
pub fn axpy(a: &mut [f32], s: f32, b: &[f32]) {
    assert_eq!(a.len(), b.len());
    for (x, &y) in a.iter_mut().zip(b) {
        *x += s * y;
    }
}

/// `a - b` elementwise.
pub fn sub(a: &[f32], b: &[f32]) -> Vec<f32> {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x - y).collect()
}

/// In-place `a -= b` — the allocation-free form of [`sub`]. The round
/// loop's SCAFFOLD fold turns each upload into a delta with this instead
/// of allocating a fresh O(dim) vector per participant.
pub fn sub_from(a: &mut [f32], b: &[f32]) {
    assert_eq!(a.len(), b.len());
    for (x, &y) in a.iter_mut().zip(b) {
        *x -= y;
    }
}

/// FedAdam server state (Adam over the aggregated pseudo-gradient).
#[derive(Clone, Debug)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub beta1: f64,
    pub beta2: f64,
    pub eta: f64,
    pub eps: f64,
    pub t: u64,
}

impl AdamState {
    /// Paper's hyper-parameters (Supp. C.5): β1=0.9, β2=0.99, η_g=0.01.
    pub fn new(dim: usize) -> AdamState {
        AdamState {
            m: vec![0.0; dim],
            v: vec![0.0; dim],
            beta1: 0.9,
            beta2: 0.99,
            eta: 0.01,
            eps: 1e-8,
            t: 0,
        }
    }

    /// Apply one server step given the mean client model `avg` and the
    /// current server model `theta`; returns the new server model.
    /// The pseudo-gradient is `Δ = avg − θ`.
    pub fn step(&mut self, theta: &[f32], avg: &[f32]) -> Vec<f32> {
        assert_eq!(theta.len(), avg.len());
        assert_eq!(theta.len(), self.m.len());
        self.t += 1;
        let b1 = self.beta1;
        let b2 = self.beta2;
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        let mut out = Vec::with_capacity(theta.len());
        for i in 0..theta.len() {
            let delta = (avg[i] - theta[i]) as f64;
            self.m[i] = (b1 * self.m[i] as f64 + (1.0 - b1) * delta) as f32;
            self.v[i] = (b2 * self.v[i] as f64 + (1.0 - b2) * delta * delta) as f32;
            let m_hat = self.m[i] as f64 / bc1;
            let v_hat = self.v[i] as f64 / bc2;
            out.push((theta[i] as f64 + self.eta * m_hat / (v_hat.sqrt() + self.eps)) as f32);
        }
        out
    }
}

/// SCAFFOLD server state: global control variate `c` and global lr.
#[derive(Clone, Debug)]
pub struct ScaffoldState {
    pub c: Vec<f32>,
    /// Global model step size on the averaged delta (Option II, η_g = 1).
    pub eta_g: f64,
    /// Total number of clients K (the c update scales by |S|/K).
    pub num_clients: usize,
}

impl ScaffoldState {
    pub fn new(dim: usize, num_clients: usize) -> ScaffoldState {
        ScaffoldState { c: vec![0.0; dim], eta_g: 1.0, num_clients }
    }

    /// Server update given the sampled clients' model deltas and control
    /// deltas: `θ += η_g·mean(Δθ)`, `c += (|S|/K)·mean(Δc)`.
    pub fn step(
        &mut self,
        theta: &[f32],
        delta_models: &[Vec<f32>],
        delta_controls: &[Vec<f32>],
    ) -> Vec<f32> {
        let s = delta_models.len();
        assert!(s > 0 && s == delta_controls.len());
        let w = vec![1.0; s];
        let mean_dm = weighted_mean(delta_models, &w);
        let mean_dc = weighted_mean(delta_controls, &w);
        self.step_from_means(theta, &mean_dm, &mean_dc, s)
    }

    /// Streaming form of [`ScaffoldState::step`]: the caller folds the
    /// per-client deltas through [`WeightedAccumulator`]s (equal weights)
    /// and hands over just the two means plus the participant count `s`.
    pub fn step_from_means(
        &mut self,
        theta: &[f32],
        mean_delta_model: &[f32],
        mean_delta_control: &[f32],
        s: usize,
    ) -> Vec<f32> {
        assert!(s > 0);
        let mut out = theta.to_vec();
        axpy(&mut out, self.eta_g as f32, mean_delta_model);
        let scale = s as f32 / self.num_clients as f32;
        axpy(&mut self.c, scale, mean_delta_control);
        out
    }
}

/// FedDyn server state `h` (Acar et al. 2021, Eq. 7-8).
#[derive(Clone, Debug)]
pub struct FedDynState {
    pub h: Vec<f32>,
    pub alpha: f64,
    pub num_clients: usize,
}

impl FedDynState {
    pub fn new(dim: usize, alpha: f64, num_clients: usize) -> FedDynState {
        FedDynState { h: vec![0.0; dim], alpha, num_clients }
    }

    /// `h ← h − α·(1/K)·Σ_{i∈S}(θ_i − θ)`; `θ⁺ = mean(θ_i) − h/α`.
    pub fn step(&mut self, theta: &[f32], client_models: &[Vec<f32>]) -> Vec<f32> {
        let s = client_models.len();
        assert!(s > 0);
        let w = vec![1.0; s];
        let avg = weighted_mean(client_models, &w);
        self.step_from_mean(theta, avg, s)
    }

    /// Streaming form of [`FedDynState::step`]: takes the pre-folded
    /// unweighted mean of the participating client models plus the
    /// participant count `s`.
    pub fn step_from_mean(&mut self, theta: &[f32], avg: Vec<f32>, s: usize) -> Vec<f32> {
        assert!(s > 0);
        let scale = (self.alpha * s as f64 / self.num_clients as f64) as f32;
        for i in 0..self.h.len() {
            self.h[i] -= scale * (avg[i] - theta[i]);
        }
        let mut out = avg;
        let inv_alpha = (1.0 / self.alpha) as f32;
        for i in 0..out.len() {
            out[i] -= inv_alpha * self.h[i];
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest as pt;
    use crate::util::rng::Rng;

    #[test]
    fn weighted_mean_basic() {
        let a = vec![vec![1.0f32, 0.0], vec![3.0, 4.0]];
        let m = weighted_mean(&a, &[1.0, 3.0]);
        assert_eq!(m, vec![2.5, 3.0]);
    }

    #[test]
    fn streaming_accumulator_matches_batch_mean() {
        let mut rng = Rng::new(31);
        let k = 7;
        let n = 33;
        let vs: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..n).map(|_| rng.gaussian() as f32).collect())
            .collect();
        let ws: Vec<f64> = (0..k).map(|_| 0.5 + rng.f64() * 3.0).collect();
        let batch = weighted_mean(&vs, &ws);
        let mut acc = WeightedAccumulator::new(n);
        for (v, &w) in vs.iter().zip(&ws) {
            acc.push(v, w);
        }
        // Bit-identical: weighted_mean is defined on top of the accumulator.
        assert_eq!(acc.mean(), batch);
        assert_eq!(acc.count(), k);
        assert!(!acc.is_empty());
    }

    #[test]
    #[should_panic(expected = "no vectors")]
    fn empty_accumulator_mean_panics() {
        WeightedAccumulator::new(4).mean();
    }

    #[test]
    fn weighted_mean_identity_on_equal_inputs() {
        let a = vec![vec![0.5f32; 8]; 5];
        let m = weighted_mean(&a, &[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!(m.iter().all(|&x| (x - 0.5).abs() < 1e-7));
    }

    #[test]
    fn prop_weighted_mean_bounds_and_permutation_invariance() {
        pt::check(
            77,
            |rng: &mut Rng| {
                let k = 2 + rng.below(5);
                let n = 1 + rng.below(16);
                let vs: Vec<Vec<f32>> = (0..k)
                    .map(|_| (0..n).map(|_| rng.gaussian() as f32).collect())
                    .collect();
                let ws: Vec<f64> = (0..k).map(|_| 0.1 + rng.f64() * 5.0).collect();
                (vs, ws)
            },
            pt::no_shrink,
            |(vs, ws)| {
                let m = weighted_mean(vs, ws);
                // Convexity: each coordinate within [min, max] of inputs.
                for i in 0..m.len() {
                    let lo = vs.iter().map(|v| v[i]).fold(f32::INFINITY, f32::min);
                    let hi = vs.iter().map(|v| v[i]).fold(f32::NEG_INFINITY, f32::max);
                    if m[i] < lo - 1e-4 || m[i] > hi + 1e-4 {
                        return Err(format!("coord {i}: {} outside [{lo},{hi}]", m[i]));
                    }
                }
                // Permutation invariance.
                let mut vs2 = vs.clone();
                let mut ws2 = ws.clone();
                vs2.rotate_left(1);
                ws2.rotate_left(1);
                let m2 = weighted_mean(&vs2, &ws2);
                for (a, b) in m.iter().zip(m2.iter()) {
                    if (a - b).abs() > 1e-5 {
                        return Err("not permutation invariant".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn adam_moves_toward_average() {
        let mut adam = AdamState::new(4);
        let theta = vec![0.0f32; 4];
        let avg = vec![1.0f32, -1.0, 2.0, 0.5];
        let out = adam.step(&theta, &avg);
        // First step moves by ~eta in the sign of delta.
        for (o, &a) in out.iter().zip(avg.iter()) {
            assert!(o.signum() == a.signum());
            assert!(o.abs() <= adam.eta as f32 * 1.5);
        }
    }

    #[test]
    fn adam_no_delta_no_move() {
        let mut adam = AdamState::new(3);
        let theta = vec![1.0f32, 2.0, 3.0];
        let out = adam.step(&theta.clone(), &theta);
        for (o, t) in out.iter().zip(theta.iter()) {
            assert!((o - t).abs() < 1e-6);
        }
    }

    #[test]
    fn scaffold_plain_average_when_eta1() {
        let mut st = ScaffoldState::new(3, 10);
        let theta = vec![1.0f32, 1.0, 1.0];
        let dm = vec![vec![0.5f32, 0.0, -0.5], vec![1.5, 0.0, -1.5]];
        let dc = vec![vec![0.1f32; 3], vec![0.3; 3]];
        let out = st.step(&theta, &dm, &dc);
        assert_eq!(out, vec![2.0, 1.0, 0.0]);
        // c updated by (2/10)·mean = 0.2·0.2 = 0.04.
        assert!((st.c[0] - 0.04).abs() < 1e-6);
    }

    #[test]
    fn feddyn_reduces_to_average_plus_drift_term() {
        let mut st = FedDynState::new(2, 0.1, 4);
        let theta = vec![0.0f32, 0.0];
        let clients = vec![vec![1.0f32, 2.0], vec![3.0, 2.0]];
        let out = st.step(&theta, &clients);
        // avg = [2, 2]; h = -alpha*(2/4)*avg = -0.05*[2,2] = [-0.1,-0.1];
        // out = avg - h/alpha = [2,2] + [1,1] = [3,3].
        assert!((out[0] - 3.0).abs() < 1e-5, "{out:?}");
        assert!((out[1] - 3.0).abs() < 1e-5);
    }

    #[test]
    fn masked_pushes_renormalize_per_coordinate() {
        // Two full clients, then one rank-truncated client active on
        // coordinate 0 only — order mixed both ways.
        let mut acc = WeightedAccumulator::new(2);
        acc.push(&[1.0, 1.0], 1.0);
        acc.push_masked(&[9.0, 0.0], 2.0, &[true, false]);
        acc.push(&[3.0, 3.0], 1.0);
        let m = acc.mean_or(&[-7.0, -7.0]);
        // coord 0: (1 + 18 + 3) / 4 = 5.5; coord 1: (1 + 3) / 2 = 2.0.
        assert!((m[0] - 5.5).abs() < 1e-6, "{m:?}");
        assert!((m[1] - 2.0).abs() < 1e-6, "{m:?}");
        assert_eq!(acc.count(), 3);
    }

    #[test]
    fn mean_or_without_masked_pushes_is_bit_identical_to_mean() {
        let mut rng = Rng::new(7);
        let mut acc = WeightedAccumulator::new(9);
        for _ in 0..5 {
            let v: Vec<f32> = (0..9).map(|_| rng.gaussian() as f32).collect();
            acc.push(&v, 0.5 + rng.f64());
        }
        let fallback = vec![123.0f32; 9];
        assert_eq!(acc.mean_or(&fallback), acc.mean());
    }

    #[test]
    fn fully_masked_coordinate_falls_back_to_previous_global() {
        let mut acc = WeightedAccumulator::new(2);
        acc.push_masked(&[4.0, 0.0], 1.5, &[true, false]);
        let m = acc.mean_or(&[0.25, 0.75]);
        assert!((m[0] - 4.0).abs() < 1e-6, "{m:?}");
        assert_eq!(m[1], 0.75);
    }

    #[test]
    fn sub_and_axpy() {
        let a = vec![3.0f32, 4.0];
        let b = vec![1.0f32, 1.5];
        assert_eq!(sub(&a, &b), vec![2.0, 2.5]);
        let mut c = a.clone();
        axpy(&mut c, 2.0, &b);
        assert_eq!(c, vec![5.0, 7.0]);
        // In-place form is bit-identical to the allocating one.
        let mut d = a.clone();
        sub_from(&mut d, &b);
        assert_eq!(d, sub(&a, &b));
    }
}
