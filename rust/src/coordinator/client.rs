//! Per-client persistent state, stored **sparsely** by the
//! [`ClientStore`](super::store::ClientStore).
//!
//! In the cross-device setting the server coordinates orders of magnitude
//! more clients than ever participate in one round, so per-client state
//! must only exist for clients that have actually been touched. A
//! `ClientRecord` holds exactly what must survive between two
//! participations of one client — everything else (the dataset, the full
//! parameter vector under full sharing) is either rematerialized on demand
//! or implied by the shared server init.

/// What persists for one *touched* client across rounds.
///
/// Which fields are populated depends on the federation's
/// [`ParamPolicy`](super::store::ParamPolicy):
///
/// * full sharing with downloads — `params` stays `None` (the next
///   download overwrites every segment, so nothing is worth keeping);
/// * partial sharing (pFedPara/FedPer) — `params` holds the dense
///   **local-segment** vector ([`Layout::gather_local`] order);
/// * local-only training — `params` holds the full parameter vector
///   (nothing is ever transferred, so everything persists on-device).
///
/// [`Layout::gather_local`]: crate::parameterization::Layout::gather_local
#[derive(Clone, Debug, Default)]
pub struct ClientRecord {
    /// Persisted parameters (policy-dependent encoding; see above).
    pub params: Option<Vec<f32>>,
    /// SCAFFOLD client control variate c_i (zeros until first update).
    pub control: Option<Vec<f32>>,
    /// FedDyn client gradient state λ_i (zeros until first update).
    pub lambda: Option<Vec<f32>>,
    /// Uplink wire error-feedback accumulator (present only when the
    /// run's up codec uses feedback and this client has transmitted).
    pub feedback: Option<Vec<f32>>,
    /// SHA-256 of the last wire global this client received, for
    /// fingerprint-cached redelivery (`None` ⇒ the client has only ever
    /// held the shared init; the store's init hash covers that case).
    pub last_global: Option<[u8; 32]>,
    /// Rounds this client has participated in (diagnostics).
    pub participations: u32,
    /// Server model version this client last trained against (async
    /// scheduling's staleness anchor; 0 ⇒ never recorded).
    pub last_version: u64,
    /// True while an async upload from this client is buffered server-side
    /// awaiting its fold turn — the sampler skips in-flight clients.
    pub in_flight: bool,
}

impl ClientRecord {
    /// Heap bytes held by this record (the store's `live_state_bytes`
    /// accounting unit).
    pub fn heap_bytes(&self) -> usize {
        let vec_bytes =
            |v: &Option<Vec<f32>>| v.as_ref().map(|v| v.capacity() * 4).unwrap_or(0);
        // `last_global` is inline (no heap) and covered by the store's
        // per-entry overhead term.
        vec_bytes(&self.params)
            + vec_bytes(&self.control)
            + vec_bytes(&self.lambda)
            + vec_bytes(&self.feedback)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_record_holds_no_heap() {
        assert_eq!(ClientRecord::default().heap_bytes(), 0);
    }

    #[test]
    fn heap_bytes_counts_all_vectors() {
        let r = ClientRecord {
            params: Some(vec![0.0; 10]),
            control: Some(vec![0.0; 4]),
            lambda: None,
            feedback: Some(vec![0.0; 6]),
            last_global: Some([0u8; 32]),
            participations: 3,
            last_version: 2,
            in_flight: true,
        };
        assert!(r.heap_bytes() >= (10 + 4 + 6) * 4);
    }
}
