//! Per-client state held by the (simulated) federation.

use std::sync::Arc;

use crate::data::Dataset;

/// One client: its private data and whatever state persists across rounds.
#[derive(Clone, Debug)]
pub struct ClientState {
    /// Private local dataset (never leaves the client). Shared by `Arc` so
    /// local-training jobs on the worker pool borrow it without copying.
    pub data: Arc<Dataset>,
    /// Full-length parameter vector. Global segments are overwritten on
    /// download; local segments (pFedPara/FedPer) persist here.
    pub params: Vec<f32>,
    /// SCAFFOLD client control variate c_i.
    pub control: Option<Vec<f32>>,
    /// FedDyn client gradient state λ_i.
    pub lambda: Option<Vec<f32>>,
    /// Rounds this client has participated in (diagnostics).
    pub participations: usize,
}

impl ClientState {
    pub fn new(data: Dataset, init_params: Vec<f32>) -> ClientState {
        ClientState {
            data: Arc::new(data),
            params: init_params,
            control: None,
            lambda: None,
            participations: 0,
        }
    }

    pub fn num_samples(&self) -> usize {
        self.data.len()
    }
}
