//! Wire codecs: every byte crossing the simulated network flows through
//! one [`WireCodec`] seam.
//!
//! The repo's original wire model was a single hardcoded rung — fp16
//! quantization on uploads (`quantize_fp16_in_place` calls sprinkled
//! through the round loop). This module turns the wire into a composable
//! layer with three rungs from the paper's communication-efficiency
//! lineage:
//!
//! * [`Identity`] — raw fp32, 4 bytes/value, bit-exact (the default; every
//!   pre-codec seeded run reproduces exactly under it);
//! * [`Fp16`] — FedPAQ-style round-to-nearest-even half precision
//!   (Supp. D.3), 2 bytes/value, bit-identical to the old
//!   `quantize_upload` path;
//! * [`SubsampleQuant`] — Konečný et al. (2016) sketched updates: a random
//!   `rate`-subset of coordinates, each probabilistically quantized to one
//!   of `levels` levels over the subset's range, delta-coded against the
//!   global the client received. An **error-feedback** accumulator
//!   (persisted per client in the sparse `ClientStore`) carries the
//!   untransmitted mass into the next round so aggressive rates don't
//!   diverge (Seide et al. 2014; Karimireddy et al. 2019).
//!
//! Two codec *slots* exist per run (`WireConfig { up, down }`): uploads are
//! encoded inside each `LocalTrainJob` with the job's own `(round, cid)`
//! rng stream (bit-deterministic and pool-size invariant), while the
//! downlink codec is applied **once per round** to the broadcast global —
//! every participant receives the same wire vector, billed per client.
//!
//! On top of the seam sits content-fingerprinted redelivery ([`Downlink`] +
//! [`global_fingerprint`]): the store remembers the SHA-256 of the last
//! wire global each client received, and a client that provably already
//! holds the current one (e.g. round 0, where every virtual client holds
//! the shared init by construction) is billed only the 32-byte hash check.
//! Fingerprinting changes billing only — never training bits.

use std::sync::Arc;

use crate::config::CodecSpec;
use crate::util::f16;
use crate::util::hash::Sha256;
use crate::util::rng::Rng;

/// Bytes billed for a fingerprint hit: the hash check itself.
pub const FINGERPRINT_BYTES: u64 = 32;

/// What actually travels: the bit-level wire representation of one dense
/// f32 vector under some codec, plus enough header to reconstruct it.
#[derive(Clone, Debug, PartialEq)]
pub enum WirePayload {
    /// Raw fp32 values (identity).
    Dense(Vec<f32>),
    /// fp16 bit patterns, one `u16` per value.
    F16(Vec<u16>),
    /// Sparse sketch: `indices[i]` carries quantization level `levels[i]`
    /// over the `[lo, hi]` range; all other coordinates are zero. `len` is
    /// the dense length the payload decodes back to.
    Sketch { len: usize, lo: f32, hi: f32, indices: Vec<u32>, levels: Vec<u8> },
}

/// One wire codec: how a dense f32 vector is represented on the simulated
/// network, what that representation is billed at, and what the receiver
/// reconstructs.
///
/// The contract ties three views of the same transformation together:
///
/// * `encode`/`decode` — the explicit payload form (what the property
///   tests and the `bench_report` wire section exercise);
/// * `transmit` — the in-place hot path the round loop runs: overwrite
///   `values` with exactly `decode(encode(...))` and return billed bytes,
///   without materializing a payload where avoidable;
/// * `billed_bytes` — the wire cost of a dense vector of a given length
///   (equals the bytes `encode`/`transmit` return).
///
/// `transmit` takes the receiver's `reference` (the wire global the client
/// downloaded — the delta base for sketch codecs; ignored by dense codecs)
/// and an optional per-client error-`feedback` accumulator. Codecs that
/// report `uses_feedback()` add the accumulator into the delta before
/// encoding and store the residual back; the accumulator itself lives in
/// the `ClientStore` and travels with the job, so parallel scheduling
/// cannot reorder its updates.
pub trait WireCodec: Send + Sync {
    fn name(&self) -> &'static str;

    /// Wire bytes for a dense vector of `len` values.
    fn billed_bytes(&self, len: usize) -> u64;

    /// Does `transmit` consult the per-client error-feedback accumulator?
    fn uses_feedback(&self) -> bool {
        false
    }

    /// True only for the raw-fp32 codec (lets broadcast paths skip copies).
    fn is_identity(&self) -> bool {
        false
    }

    /// Serialize `values` into a wire payload; returns `(payload, bytes)`.
    /// For sketch codecs `values` is the delta being sketched.
    fn encode(&self, values: &[f32], rng: &mut Rng) -> (WirePayload, u64);

    /// Reconstruct the receiver-side dense vector from a payload.
    fn decode(&self, payload: &WirePayload) -> Vec<f32>;

    /// In-place wire round-trip: overwrite `values` with what the receiver
    /// will see and return billed bytes.
    fn transmit(
        &self,
        values: &mut [f32],
        reference: Option<&[f32]>,
        feedback: Option<&mut Vec<f32>>,
        rng: &mut Rng,
    ) -> u64;
}

/// Raw fp32: the wire is a window, 4 bytes/value.
pub struct Identity;

impl WireCodec for Identity {
    fn name(&self) -> &'static str {
        "identity"
    }

    fn billed_bytes(&self, len: usize) -> u64 {
        (len * 4) as u64
    }

    fn is_identity(&self) -> bool {
        true
    }

    fn encode(&self, values: &[f32], _rng: &mut Rng) -> (WirePayload, u64) {
        (WirePayload::Dense(values.to_vec()), self.billed_bytes(values.len()))
    }

    fn decode(&self, payload: &WirePayload) -> Vec<f32> {
        match payload {
            WirePayload::Dense(v) => v.clone(),
            other => panic!("identity cannot decode {other:?}"),
        }
    }

    fn transmit(
        &self,
        values: &mut [f32],
        _reference: Option<&[f32]>,
        _feedback: Option<&mut Vec<f32>>,
        _rng: &mut Rng,
    ) -> u64 {
        self.billed_bytes(values.len())
    }
}

/// IEEE fp16 with round-to-nearest-even, 2 bytes/value — the FedPAQ rung.
/// `transmit` is exactly the old `comm::quantize_fp16_in_place` path.
pub struct Fp16;

impl WireCodec for Fp16 {
    fn name(&self) -> &'static str {
        "fp16"
    }

    fn billed_bytes(&self, len: usize) -> u64 {
        (len * 2) as u64
    }

    fn encode(&self, values: &[f32], _rng: &mut Rng) -> (WirePayload, u64) {
        let mut bits = Vec::new();
        f16::quantize(values, &mut bits);
        (WirePayload::F16(bits), self.billed_bytes(values.len()))
    }

    fn decode(&self, payload: &WirePayload) -> Vec<f32> {
        match payload {
            WirePayload::F16(bits) => {
                let mut out = Vec::new();
                f16::dequantize(bits, &mut out);
                out
            }
            other => panic!("fp16 cannot decode {other:?}"),
        }
    }

    fn transmit(
        &self,
        values: &mut [f32],
        _reference: Option<&[f32]>,
        _feedback: Option<&mut Vec<f32>>,
        _rng: &mut Rng,
    ) -> u64 {
        f16::quantize_roundtrip_in_place(values);
        self.billed_bytes(values.len())
    }
}

/// Konečný-style sketched update: `rate`-subsampling + probabilistic
/// `levels`-level quantization over the sampled range, with optional
/// error feedback.
///
/// Wire format (and the billing formula): an 8-byte `[lo, hi]` header plus
/// 5 bytes per sampled coordinate (4-byte index + 1-byte level; `levels`
/// ≤ 256 is enforced at parse/validate time so a level always fits one
/// byte).
pub struct SubsampleQuant {
    pub rate: f64,
    pub levels: u32,
    pub feedback: bool,
}

impl SubsampleQuant {
    /// Sampled coordinate count for a dense length `n`.
    fn k(&self, n: usize) -> usize {
        if n == 0 {
            return 0;
        }
        ((n as f64 * self.rate).ceil() as usize).clamp(1, n)
    }
}

impl WireCodec for SubsampleQuant {
    fn name(&self) -> &'static str {
        "subsample_quant"
    }

    fn billed_bytes(&self, len: usize) -> u64 {
        let k = self.k(len) as u64;
        if k == 0 {
            return 0;
        }
        8 + k * 5
    }

    fn uses_feedback(&self) -> bool {
        self.feedback
    }

    fn encode(&self, values: &[f32], rng: &mut Rng) -> (WirePayload, u64) {
        let n = values.len();
        let k = self.k(n);
        let idx = rng.sample_indices(n, k);
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &j in &idx {
            lo = lo.min(values[j]);
            hi = hi.max(values[j]);
        }
        if k == 0 {
            (lo, hi) = (0.0, 0.0);
        }
        let unit = (hi - lo) as f64 / (self.levels - 1).max(1) as f64;
        let top = (self.levels - 1) as f64;
        let mut lvls = Vec::with_capacity(k);
        for &j in &idx {
            // One draw per sampled coordinate regardless of the rounding
            // outcome: the rng stream length is fixed by (n, k) alone.
            let draw = rng.f64();
            let level = if unit <= 0.0 {
                0u32
            } else {
                // Probabilistic (unbiased) rounding: value v between levels
                // b and b+1 rounds up with probability equal to its
                // fractional position — E[decoded] = v.
                let pos = ((values[j] - lo) as f64 / unit).clamp(0.0, top);
                let base = pos.floor();
                let up = (draw < pos - base) as u32;
                (base as u32 + up).min(self.levels - 1)
            };
            lvls.push(level as u8);
        }
        let indices = idx.into_iter().map(|j| j as u32).collect();
        (
            WirePayload::Sketch { len: n, lo, hi, indices, levels: lvls },
            self.billed_bytes(n),
        )
    }

    fn decode(&self, payload: &WirePayload) -> Vec<f32> {
        let WirePayload::Sketch { len, lo, hi, indices, levels } = payload else {
            panic!("subsample_quant cannot decode {payload:?}");
        };
        let unit = (hi - lo) as f64 / (self.levels - 1).max(1) as f64;
        let mut out = vec![0f32; *len];
        for (&j, &l) in indices.iter().zip(levels.iter()) {
            out[j as usize] = (*lo as f64 + l as f64 * unit) as f32;
        }
        out
    }

    fn transmit(
        &self,
        values: &mut [f32],
        reference: Option<&[f32]>,
        feedback: Option<&mut Vec<f32>>,
        rng: &mut Rng,
    ) -> u64 {
        let n = values.len();
        if let Some(r) = reference {
            assert_eq!(r.len(), n, "wire reference length mismatch");
        }
        // The sketch input: d = (values − reference) + feedback.
        let fb = if self.feedback { feedback } else { None };
        let mut d = vec![0f32; n];
        for j in 0..n {
            d[j] = values[j] - reference.map_or(0.0, |r| r[j]);
        }
        if let Some(fb) = fb.as_deref() {
            if !fb.is_empty() {
                assert_eq!(fb.len(), n, "error-feedback accumulator length mismatch");
                for j in 0..n {
                    d[j] += fb[j];
                }
            }
        }
        let (payload, bytes) = self.encode(&d, rng);
        let t = self.decode(&payload);
        for j in 0..n {
            values[j] = reference.map_or(0.0, |r| r[j]) + t[j];
        }
        if let Some(fb) = fb {
            // The residual — everything the wire didn't carry — rides into
            // the next round's delta.
            fb.clear();
            fb.extend(d.iter().zip(t.iter()).map(|(dj, tj)| dj - tj));
        }
        bytes
    }
}

/// Instantiate the codec a [`CodecSpec`] describes.
pub fn codec_for(spec: &CodecSpec) -> Arc<dyn WireCodec> {
    match spec {
        CodecSpec::Identity => Arc::new(Identity),
        CodecSpec::Fp16 => Arc::new(Fp16),
        CodecSpec::SubsampleQuant { rate, levels, feedback } => {
            Arc::new(SubsampleQuant { rate: *rate, levels: *levels, feedback: *feedback })
        }
    }
}

/// Content fingerprint of a wire global: SHA-256 over the exact f32 bit
/// patterns (little-endian), so two globals match iff they are
/// bit-identical — the determinism the redelivery cache rests on.
pub fn global_fingerprint(values: &[f32]) -> [u8; 32] {
    let mut h = Sha256::new();
    let mut buf = [0u8; 4 * 1024];
    for chunk in values.chunks(1024) {
        let mut used = 0;
        for &v in chunk {
            buf[used..used + 4].copy_from_slice(&v.to_bits().to_le_bytes());
            used += 4;
        }
        h.update(&buf[..used]);
    }
    h.finalize()
}

/// Server-side downlink state: applies the down codec **once per round**
/// to the broadcast global (every participant receives the same wire
/// vector) and fingerprints the result for redelivery caching.
pub struct Downlink {
    codec: Arc<dyn WireCodec>,
    fingerprint: bool,
    rng: Rng,
}

/// Seed tag for the downlink's codec rng: one stream per federation,
/// separate from the root/sampler/client streams.
const DOWNLINK_RNG_TAG: u64 = 0xD01C_0DEC;

impl Downlink {
    pub fn new(spec: &CodecSpec, fingerprint: bool, seed: u64) -> Downlink {
        Downlink { codec: codec_for(spec), fingerprint, rng: Rng::new(seed ^ DOWNLINK_RNG_TAG) }
    }

    /// Encode this round's broadcast: returns the wire global (shared by
    /// all participants), the per-client billed bytes for it, and — when
    /// fingerprinting is on — its content hash. Identity broadcasts are
    /// zero-copy and consume no rng, preserving the pre-codec bit path.
    pub fn broadcast(&mut self, raw: &Arc<Vec<f32>>) -> (Arc<Vec<f32>>, u64, Option<[u8; 32]>) {
        let (wire, bytes) = if self.codec.is_identity() {
            (Arc::clone(raw), self.codec.billed_bytes(raw.len()))
        } else {
            let mut v = raw.as_ref().clone();
            let bytes = self.codec.transmit(&mut v, None, None, &mut self.rng);
            (Arc::new(v), bytes)
        };
        let hash = self.fingerprint.then(|| global_fingerprint(&wire));
        (wire, bytes, hash)
    }

    /// Billed bytes for a dense side-channel broadcast of `len` values
    /// (SCAFFOLD's server control variate rides the same downlink codec).
    pub fn side_bytes(&self, len: usize) -> u64 {
        self.codec.billed_bytes(len)
    }

    /// Apply the downlink codec to a dense side-channel vector (no delta
    /// reference, no feedback) and return billed bytes.
    pub fn side_transmit(&mut self, values: &mut [f32]) -> u64 {
        if self.codec.is_identity() {
            return self.codec.billed_bytes(values.len());
        }
        self.codec.transmit(values, None, None, &mut self.rng)
    }

    pub fn fingerprinting(&self) -> bool {
        self.fingerprint
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WireConfig;

    fn sketch(rate: f64, levels: u32, feedback: bool) -> SubsampleQuant {
        SubsampleQuant { rate, levels, feedback }
    }

    fn ramp(n: usize) -> Vec<f32> {
        (0..n).map(|i| (i as f32 / n as f32) * 2.0 - 1.0).collect()
    }

    #[test]
    fn identity_transmit_is_noop_and_bills_fp32() {
        let mut v = ramp(37);
        let orig = v.clone();
        let mut rng = Rng::new(1);
        let bytes = Identity.transmit(&mut v, None, None, &mut rng);
        assert_eq!(v, orig, "identity must not alter values");
        assert_eq!(bytes, 37 * 4);
        // And the rng is untouched (bit path preserved).
        assert_eq!(rng.next_u64(), Rng::new(1).next_u64());
    }

    #[test]
    fn fp16_transmit_matches_legacy_quantizer() {
        let vals: Vec<f32> = vec![0.1, -2.5, 65504.0, 1e-8, -0.0, 3.14159, 1e5];
        let mut wire = vals.clone();
        let mut rng = Rng::new(2);
        let bytes = Fp16.transmit(&mut wire, None, None, &mut rng);
        let (legacy, legacy_bytes) = super::super::comm::quantize_fp16(&vals);
        assert_eq!(bytes, legacy_bytes);
        for (a, b) in wire.iter().zip(legacy.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "fp16 codec diverged from legacy path");
        }
    }

    #[test]
    fn encode_decode_roundtrip_dense_codecs() {
        let vals = ramp(101); // Odd length on purpose.
        let mut rng = Rng::new(3);
        let (p, bytes) = Identity.encode(&vals, &mut rng);
        assert_eq!(bytes, 101 * 4);
        assert_eq!(Identity.decode(&p), vals);

        let (p, bytes) = Fp16.encode(&vals, &mut rng);
        assert_eq!(bytes, 101 * 2);
        let dec = Fp16.decode(&p);
        let direct = f16::quantize_roundtrip(&vals);
        assert_eq!(dec.len(), vals.len());
        for (a, b) in dec.iter().zip(direct.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn sketch_roundtrip_hits_sampled_coords_within_one_level() {
        let c = sketch(0.25, 16, true);
        let vals = ramp(200);
        let mut rng = Rng::new(4);
        let (p, bytes) = c.encode(&vals, &mut rng);
        assert_eq!(bytes, c.billed_bytes(200));
        assert_eq!(bytes, 8 + 50 * 5, "k = ceil(0.25·200) = 50 at 5 B/coord + 8 B header");
        let dec = c.decode(&p);
        assert_eq!(dec.len(), 200);
        let WirePayload::Sketch { lo, hi, indices, .. } = &p else { unreachable!() };
        assert_eq!(indices.len(), 50);
        let unit = (hi - lo) / 15.0;
        let sampled: std::collections::HashSet<u32> = indices.iter().copied().collect();
        for j in 0..200u32 {
            if sampled.contains(&j) {
                // Probabilistic rounding lands on an adjacent level.
                assert!(
                    (dec[j as usize] - vals[j as usize]).abs() <= unit + 1e-6,
                    "sampled coord {j} off by more than one level"
                );
            } else {
                assert_eq!(dec[j as usize], 0.0, "unsampled coord {j} must decode to zero");
            }
        }
    }

    #[test]
    fn sketch_transmit_composes_encode_decode() {
        let c = sketch(0.5, 8, true);
        let reference = ramp(64);
        let upload: Vec<f32> = ramp(64).iter().map(|x| x * 0.9 + 0.05).collect();
        let mut fb = vec![0f32; 64];

        // By hand: d = upload − reference (fb is zero), then encode/decode.
        let d: Vec<f32> = upload.iter().zip(reference.iter()).map(|(u, r)| u - r).collect();
        let (p, want_bytes) = c.encode(&d, &mut Rng::new(9));
        let t = c.decode(&p);
        let want: Vec<f32> = reference.iter().zip(t.iter()).map(|(r, t)| r + t).collect();

        let mut got = upload.clone();
        let bytes = c.transmit(&mut got, Some(&reference), Some(&mut fb), &mut Rng::new(9));
        assert_eq!(bytes, want_bytes);
        for (a, b) in got.iter().zip(want.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "transmit != ref + decode(encode(d))");
        }
        // Residual bookkeeping: fb = d − t.
        for ((fbj, dj), tj) in fb.iter().zip(d.iter()).zip(t.iter()) {
            assert_eq!(fbj.to_bits(), (dj - tj).to_bits());
        }
    }

    /// The error-feedback property the convergence story rests on: when
    /// the same vector is transmitted T times with a persistent
    /// accumulator, the mean received update approaches the true vector
    /// (cumulative error = fb_T, which stays bounded), while without
    /// feedback the mean is biased by the sampling rate — the sketch
    /// only ever delivers `rate` of the mass.
    #[test]
    fn error_feedback_preserves_transmitted_mass() {
        let n = 32;
        let target = ramp(n);
        let rounds = 200;

        let mean_received = |feedback: bool| -> Vec<f64> {
            let c = sketch(0.5, 16, feedback);
            let mut fb = vec![0f32; n];
            let mut rng = Rng::new(12);
            let mut sum = vec![0f64; n];
            for _ in 0..rounds {
                let mut v = target.clone();
                c.transmit(&mut v, None, Some(&mut fb), &mut rng);
                for j in 0..n {
                    sum[j] += v[j] as f64;
                }
            }
            sum.iter().map(|s| s / rounds as f64).collect()
        };

        let with_fb = mean_received(true);
        let without_fb = mean_received(false);
        let max_err = |m: &[f64]| {
            m.iter()
                .zip(target.iter())
                .map(|(a, b)| (a - *b as f64).abs())
                .fold(0.0f64, f64::max)
        };
        let err_fb = max_err(&with_fb);
        let err_nofb = max_err(&without_fb);
        assert!(err_fb < 0.1, "with feedback the mean update must approach the target: {err_fb}");
        assert!(
            err_nofb > 0.25,
            "without feedback the rate-0.5 sketch should be visibly biased: {err_nofb}"
        );
    }

    #[test]
    fn sketch_feedback_off_leaves_accumulator_untouched() {
        let c = sketch(0.5, 16, false);
        assert!(!c.uses_feedback());
        let mut v = ramp(16);
        let mut fb = vec![7.0f32; 16];
        c.transmit(&mut v, None, Some(&mut fb), &mut Rng::new(5));
        assert_eq!(fb, vec![7.0f32; 16], "nofb codec must ignore the accumulator");
    }

    #[test]
    fn billed_bytes_formulas() {
        assert_eq!(Identity.billed_bytes(0), 0);
        assert_eq!(Identity.billed_bytes(7), 28);
        assert_eq!(Fp16.billed_bytes(7), 14, "odd lengths bill exactly 2·len");
        let c = sketch(0.1, 16, true);
        assert_eq!(c.billed_bytes(0), 0);
        // k = ceil(0.1·7) = 1.
        assert_eq!(c.billed_bytes(7), 8 + 5);
        // rate 1.0 samples everything: 8 + 5n > 4n — the codec is honest
        // about being a poor choice at full rate.
        assert_eq!(sketch(1.0, 16, true).billed_bytes(100), 8 + 500);
    }

    #[test]
    fn codec_for_matches_spec() {
        assert!(codec_for(&CodecSpec::Identity).is_identity());
        assert_eq!(codec_for(&CodecSpec::Fp16).name(), "fp16");
        let c = codec_for(&CodecSpec::SubsampleQuant { rate: 0.2, levels: 4, feedback: true });
        assert_eq!(c.name(), "subsample_quant");
        assert!(c.uses_feedback());
        assert!(!codec_for(&CodecSpec::SubsampleQuant {
            rate: 0.2,
            levels: 4,
            feedback: false
        })
        .uses_feedback());
    }

    #[test]
    fn fingerprint_is_content_addressed() {
        let a = ramp(100);
        let mut b = ramp(100);
        assert_eq!(global_fingerprint(&a), global_fingerprint(&b));
        b[99] = f32::from_bits(b[99].to_bits() ^ 1); // One bit flip.
        assert_ne!(global_fingerprint(&a), global_fingerprint(&b));
        // Chunked hashing matches a one-shot hash (chunk boundary at 1024).
        let long = ramp(3000);
        let mut h = Sha256::new();
        for v in &long {
            h.update(&v.to_bits().to_le_bytes());
        }
        assert_eq!(global_fingerprint(&long), h.finalize());
    }

    #[test]
    fn identity_downlink_is_zero_copy() {
        let raw = Arc::new(ramp(50));
        let mut dl = Downlink::new(&CodecSpec::Identity, false, 42);
        let (wire, bytes, hash) = dl.broadcast(&raw);
        assert!(Arc::ptr_eq(&raw, &wire), "identity broadcast must not copy");
        assert_eq!(bytes, 200);
        assert!(hash.is_none());
    }

    #[test]
    fn fp16_downlink_compresses_the_broadcast() {
        let raw = Arc::new(ramp(50));
        let mut dl = Downlink::new(&CodecSpec::Fp16, true, 42);
        let (wire, bytes, hash) = dl.broadcast(&raw);
        assert_eq!(bytes, 100, "fp16 downlink bills 2 B/value");
        let want = f16::quantize_roundtrip(&raw);
        for (a, b) in wire.iter().zip(want.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(hash, Some(global_fingerprint(&wire)));
    }

    #[test]
    fn wire_config_default_is_bitpath() {
        // The whole refactor rests on this: a default WireConfig is the
        // identity wire, so every pre-codec RunConfig behaves unchanged.
        let w = WireConfig::default();
        assert_eq!(w.up, CodecSpec::Identity);
        assert_eq!(w.down, CodecSpec::Identity);
        assert!(!w.fingerprint_downloads);
    }
}
