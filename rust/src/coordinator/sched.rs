//! Deterministic discrete-event scheduling for the round loop: virtual
//! per-client latencies, fault injection, and the straggler-tolerant round
//! policies (`sync` / `deadline` / `async`).
//!
//! # Virtual time
//!
//! The clock is *analytic*, never the host wall clock: a client's arrival
//! time is `down_secs(billed download) + compute + up_secs(billed upload)`,
//! where compute is the runtime's FLOP estimate divided by the device's
//! throughput, scaled by a per-client slowdown multiplier drawn
//! log-uniformly from `[1, speed_spread]` on a stream keyed by
//! `(seed, cid)` alone. Nothing here consults threads or timers, so
//! simulated times are bit-deterministic and thread-count invariant by
//! construction. Events are totally ordered by `(time, seq)` — `seq` is a
//! global arrival counter that breaks exact ties.
//!
//! # Determinism contract
//!
//! Client training RNG streams stay keyed by `(round, cid)` exactly as the
//! barrier loop draws them; the scheduler derives its own *read-only*
//! child streams (speed: `seed ^ SPEED_TAG`; faults: `seed ^ FAULT_TAG`,
//! only when faults are enabled), so `RoundPolicy::Sync` with faults off
//! is bit-identical to the historical path — pinned by
//! `tests/sched_equivalence.rs`.

use std::collections::HashMap;

use crate::config::{RoundPolicy, SchedConfig};
use crate::util::rng::Rng;

use super::comm::Network;

/// Stream tag for per-client device-speed multipliers.
const SPEED_TAG: u64 = 0x5BEE_DD0C_5BEE_DD0C;
/// Stream tag for per-(round, cid) fault draws.
const FAULT_TAG: u64 = 0xFA17_0B0B_FA17_0B0B;

/// One scheduled event; `seq` breaks exact time ties deterministically.
#[derive(Clone, Debug)]
pub struct Event<T> {
    pub time: f64,
    pub seq: u64,
    pub payload: T,
}

/// A queue of events with a total, insertion-order-independent ordering:
/// ascending `(time, seq)`, times compared by `total_cmp`.
#[derive(Clone, Debug, Default)]
pub struct EventQueue<T> {
    events: Vec<Event<T>>,
}

impl<T> EventQueue<T> {
    pub fn new() -> EventQueue<T> {
        EventQueue { events: Vec::new() }
    }

    pub fn push(&mut self, time: f64, seq: u64, payload: T) {
        self.events.push(Event { time, seq, payload });
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Consume the queue in event order.
    pub fn drain_sorted(mut self) -> Vec<Event<T>> {
        self.events
            .sort_by(|a, b| a.time.total_cmp(&b.time).then(a.seq.cmp(&b.seq)));
        self.events
    }
}

/// What the fault model decreed for one sampled client this round.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fate {
    /// Trains and uploads normally.
    Healthy,
    /// Offline before training: download billed, nothing trained.
    Dropout,
    /// Crashes mid-upload: trains, bills `frac` of the upload, then dies —
    /// the update never reaches the server.
    CrashUpload { frac: f64 },
}

/// Per-fresh-job verdict from [`Scheduler::plan`], in job order.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Decision {
    /// Fold into this round's aggregate (fresh arrivals have staleness 0,
    /// so their discount is exactly 1).
    Admit,
    /// Async: the upload lands after this round's buffer filled — carry it
    /// in the scheduler and fold it in a later round, discounted.
    Defer,
    /// Deadline: arrived too late; the update is discarded (and the client
    /// optionally re-queued).
    Straggle,
}

/// A carried update that this round's plan admitted: fold `upload` with
/// the staleness-discounted `weight`.
#[derive(Clone, Debug)]
pub struct ReadyUpdate {
    pub cid: usize,
    pub upload: Vec<f32>,
    pub weight: f64,
}

/// The plan for one round, computed *before* any job runs — admission
/// depends only on arrival times, so the expensive fold stays streaming.
#[derive(Clone, Debug, Default)]
pub struct RoundPlan {
    /// Verdict per fresh job, same order as the `arrivals` slice.
    pub decisions: Vec<Decision>,
    /// Buffered updates from earlier rounds whose turn has come, in
    /// deterministic `(finish time, seq)` order, weights pre-discounted.
    pub ready: Vec<ReadyUpdate>,
    /// Clients whose buffered updates exceeded the staleness bound and
    /// were discarded this round.
    pub dropped_cids: Vec<usize>,
    /// Fresh jobs that missed the deadline.
    pub stragglers: usize,
    /// Simulated seconds this round occupies on the event clock.
    pub round_secs: f64,
}

/// An upload buffered across rounds (async policy).
#[derive(Clone, Debug)]
struct Buffered {
    cid: usize,
    seq: u64,
    /// Absolute virtual arrival time.
    finish_abs: f64,
    /// Server version the client trained against.
    snapshot_version: u64,
    upload: Vec<f32>,
    weight: f64,
}

/// Defer bookkeeping between `plan` and the fold delivering the outcome.
#[derive(Clone, Copy, Debug)]
struct DeferSlot {
    seq: u64,
    finish_abs: f64,
    snapshot_version: u64,
}

/// The event-driven round scheduler. Owns the virtual clock, the server
/// version counter, the cross-round upload buffer, and the retry queue.
#[derive(Debug)]
pub struct Scheduler {
    cfg: SchedConfig,
    net: Network,
    seed: u64,
    /// Absolute virtual time at the start of the current round.
    clock: f64,
    /// Server model version: increments once per applied aggregation.
    version: u64,
    /// Global arrival sequence counter (ties on the event clock).
    seq: u64,
    /// Async: uploads that arrived after their round's buffer filled.
    buffer: Vec<Buffered>,
    /// Plan-time metadata for this round's deferred jobs, keyed by cid.
    planned_defers: HashMap<usize, DeferSlot>,
    /// Clients queued for re-selection next round (`faults.retry_failed`).
    retry: Vec<usize>,
}

impl Scheduler {
    pub fn new(cfg: SchedConfig, seed: u64) -> Scheduler {
        let net = Network::asymmetric(cfg.time.up_mbps, cfg.time.down_mbps);
        Scheduler {
            cfg,
            net,
            seed,
            clock: 0.0,
            version: 0,
            seq: 0,
            buffer: Vec::new(),
            planned_defers: HashMap::new(),
            retry: Vec::new(),
        }
    }

    pub fn policy(&self) -> RoundPolicy {
        self.cfg.policy
    }

    /// Absolute virtual time at the start of the current round.
    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Current server model version.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Per-client device slowdown in `[1, speed_spread]`, log-uniform and
    /// fixed for the whole run — a device class, not per-round jitter.
    pub fn speed_mult(&self, cid: usize) -> f64 {
        let spread = self.cfg.time.speed_spread;
        if spread <= 1.0 {
            return 1.0;
        }
        let u = Rng::new(self.seed ^ SPEED_TAG).child(cid as u64).f64();
        (spread.ln() * u).exp()
    }

    /// Simulated seconds from broadcast to upload landing for one client.
    pub fn arrival_secs(&self, cid: usize, down_bytes: u64, up_bytes: u64, comp_secs: f64) -> f64 {
        self.net.down_secs(down_bytes)
            + comp_secs * self.speed_mult(cid)
            + self.net.up_secs(up_bytes)
    }

    /// Draw this client's fate for the round. With faults disabled no rng
    /// stream is even constructed, so `none` can never perturb a run.
    pub fn fate(&self, round: usize, cid: usize) -> Fate {
        let f = self.cfg.faults;
        if !f.enabled() {
            return Fate::Healthy;
        }
        let mut rng =
            Rng::new(self.seed ^ FAULT_TAG).child(((round as u64) << 32) | cid as u64);
        if rng.f64() < f.dropout {
            return Fate::Dropout;
        }
        if rng.f64() < f.crash_upload {
            return Fate::CrashUpload { frac: rng.f64() };
        }
        Fate::Healthy
    }

    /// Queue a failed/straggling client for next round, if retries are on.
    pub fn note_failure(&mut self, cid: usize) {
        if self.cfg.faults.retry_failed {
            self.retry.push(cid);
        }
    }

    /// Drain the retry queue (sorted, deduplicated).
    pub fn take_retries(&mut self) -> Vec<usize> {
        let mut r = std::mem::take(&mut self.retry);
        r.sort_unstable();
        r.dedup();
        r
    }

    /// Decide the round before any job runs: which fresh arrivals fold now,
    /// which buffered updates' turn has come, and how long the round takes
    /// on the virtual clock. `arrivals` is `(cid, relative seconds)` in job
    /// order for this round's healthy participants.
    pub fn plan(&mut self, arrivals: &[(usize, f64)]) -> RoundPlan {
        self.planned_defers.clear();
        match self.cfg.policy {
            RoundPolicy::Sync => {
                let round_secs = arrivals.iter().map(|&(_, t)| t).fold(0.0f64, f64::max);
                self.seq += arrivals.len() as u64;
                RoundPlan {
                    decisions: vec![Decision::Admit; arrivals.len()],
                    round_secs,
                    ..Default::default()
                }
            }
            RoundPolicy::SyncDeadline { deadline_secs, .. } => {
                let mut decisions = Vec::with_capacity(arrivals.len());
                let mut stragglers = 0;
                let mut latest_admitted = 0.0f64;
                for &(_, t) in arrivals {
                    if t <= deadline_secs {
                        decisions.push(Decision::Admit);
                        latest_admitted = latest_admitted.max(t);
                    } else {
                        decisions.push(Decision::Straggle);
                        stragglers += 1;
                    }
                }
                self.seq += arrivals.len() as u64;
                // The barrier lifts when the last admitted client lands —
                // or at the deadline itself if anyone had to be cut off.
                let round_secs = if stragglers > 0 { deadline_secs } else { latest_admitted };
                RoundPlan { decisions, stragglers, round_secs, ..Default::default() }
            }
            RoundPolicy::Async { buffer_k, beta, max_staleness } => {
                self.plan_async(arrivals, buffer_k, beta, max_staleness)
            }
        }
    }

    /// FedBuff-style admission: merge the carried buffer with this round's
    /// fresh arrivals on the event clock, drop over-stale carries, admit
    /// the first `buffer_k` events, and defer the rest.
    fn plan_async(
        &mut self,
        arrivals: &[(usize, f64)],
        buffer_k: usize,
        beta: f64,
        max_staleness: usize,
    ) -> RoundPlan {
        #[derive(Clone, Copy)]
        enum Src {
            Carried(usize),
            Fresh(usize),
        }

        // Over-stale carries are discarded before admission.
        let mut dropped_cids = Vec::new();
        let carried = std::mem::take(&mut self.buffer);
        let mut live = Vec::with_capacity(carried.len());
        for b in carried {
            if (self.version - b.snapshot_version) as usize > max_staleness {
                dropped_cids.push(b.cid);
                let cid = b.cid;
                self.note_failure(cid);
            } else {
                live.push(b);
            }
        }

        let mut q = EventQueue::new();
        for (i, b) in live.iter().enumerate() {
            q.push(b.finish_abs, b.seq, Src::Carried(i));
        }
        let seq_base = self.seq;
        for (i, &(_, t)) in arrivals.iter().enumerate() {
            q.push(self.clock + t, seq_base + i as u64, Src::Fresh(i));
        }
        self.seq += arrivals.len() as u64;

        let mut decisions = vec![Decision::Defer; arrivals.len()];
        let mut ready = Vec::new();
        let mut carried_deferred: Vec<bool> = vec![false; live.len()];
        let mut round_end = self.clock;
        for (admitted, ev) in q.drain_sorted().into_iter().enumerate() {
            if admitted < buffer_k {
                round_end = round_end.max(ev.time);
                match ev.payload {
                    Src::Carried(i) => {
                        let b = &live[i];
                        let staleness = (self.version - b.snapshot_version) as f64;
                        let discount = 1.0 / (1.0 + staleness).powf(beta);
                        ready.push(ReadyUpdate {
                            cid: b.cid,
                            upload: b.upload.clone(),
                            weight: b.weight * discount,
                        });
                    }
                    Src::Fresh(i) => decisions[i] = Decision::Admit,
                }
            } else {
                match ev.payload {
                    Src::Carried(i) => carried_deferred[i] = true,
                    Src::Fresh(i) => {
                        self.planned_defers.insert(
                            arrivals[i].0,
                            DeferSlot {
                                seq: ev.seq,
                                finish_abs: ev.time,
                                snapshot_version: self.version,
                            },
                        );
                        debug_assert_eq!(decisions[i], Decision::Defer);
                    }
                }
            }
        }
        // Carries that didn't make this buffer stay carried.
        for (i, b) in live.into_iter().enumerate() {
            if carried_deferred[i] {
                self.buffer.push(b);
            }
        }
        RoundPlan {
            decisions,
            ready,
            dropped_cids,
            stragglers: 0,
            round_secs: round_end - self.clock,
        }
    }

    /// Hand a deferred fresh outcome to the cross-round buffer. Must match
    /// a `Decision::Defer` from this round's plan.
    pub fn buffer_upload(&mut self, cid: usize, upload: Vec<f32>, weight: f64) {
        let slot = self
            .planned_defers
            .remove(&cid)
            .expect("buffer_upload without a planned defer");
        self.buffer.push(Buffered {
            cid,
            seq: slot.seq,
            finish_abs: slot.finish_abs,
            snapshot_version: slot.snapshot_version,
            upload,
            weight,
        });
    }

    /// Number of uploads currently carried across rounds.
    pub fn buffered(&self) -> usize {
        self.buffer.len()
    }

    /// Advance the clock past the round; bump the server version iff an
    /// aggregate was applied.
    pub fn end_round(&mut self, aggregated: bool, round_secs: f64) {
        self.clock += round_secs;
        if aggregated {
            self.version += 1;
        }
        self.planned_defers.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FaultConfig, TimeModel};

    fn sched(policy: RoundPolicy, faults: FaultConfig, spread: f64, seed: u64) -> Scheduler {
        let cfg = SchedConfig {
            policy,
            faults,
            time: TimeModel { speed_spread: spread, ..Default::default() },
        };
        Scheduler::new(cfg, seed)
    }

    #[test]
    fn event_queue_order_is_insertion_invariant() {
        let evs = [(3.0, 7u64, 'a'), (1.0, 2, 'b'), (2.0, 5, 'c'), (1.0, 1, 'd')];
        let mut fwd = EventQueue::new();
        for &(t, s, p) in &evs {
            fwd.push(t, s, p);
        }
        let mut rev = EventQueue::new();
        for &(t, s, p) in evs.iter().rev() {
            rev.push(t, s, p);
        }
        let a: Vec<char> = fwd.drain_sorted().into_iter().map(|e| e.payload).collect();
        let b: Vec<char> = rev.drain_sorted().into_iter().map(|e| e.payload).collect();
        assert_eq!(a, b);
        assert_eq!(a, vec!['d', 'b', 'c', 'a'], "time first, then seq");
    }

    #[test]
    fn event_queue_breaks_exact_ties_by_seq() {
        let mut q = EventQueue::new();
        q.push(5.0, 9, "late");
        q.push(5.0, 3, "early");
        q.push(5.0, 6, "mid");
        let order: Vec<&str> = q.drain_sorted().into_iter().map(|e| e.payload).collect();
        assert_eq!(order, vec!["early", "mid", "late"]);
    }

    #[test]
    fn speed_multipliers_are_deterministic_and_bounded() {
        let s = sched(RoundPolicy::Sync, FaultConfig::default(), 100.0, 42);
        let t = sched(RoundPolicy::Sync, FaultConfig::default(), 100.0, 42);
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for cid in 0..256 {
            let m = s.speed_mult(cid);
            assert_eq!(m.to_bits(), t.speed_mult(cid).to_bits(), "cid {cid}");
            assert!((1.0..=100.0).contains(&m), "cid {cid}: {m}");
            lo = lo.min(m);
            hi = hi.max(m);
        }
        assert!(hi / lo > 10.0, "spread 100 fleet should span >10x, got {}", hi / lo);
        // Homogeneous fleet: exactly 1, no rng drawn.
        let h = sched(RoundPolicy::Sync, FaultConfig::default(), 1.0, 42);
        assert_eq!(h.speed_mult(0), 1.0);
        assert_eq!(h.speed_mult(123), 1.0);
    }

    #[test]
    fn fates_are_deterministic_and_respect_rates() {
        let faults = FaultConfig { dropout: 0.2, crash_upload: 0.1, retry_failed: false };
        let s = sched(RoundPolicy::Sync, faults, 1.0, 7);
        let t = sched(RoundPolicy::Sync, faults, 1.0, 7);
        let mut drops = 0;
        let mut crashes = 0;
        let n = 4000usize;
        for i in 0..n {
            let (round, cid) = (i / 100, i % 100);
            let f = s.fate(round, cid);
            assert_eq!(f, t.fate(round, cid));
            match f {
                Fate::Dropout => drops += 1,
                Fate::CrashUpload { frac } => {
                    assert!((0.0..1.0).contains(&frac));
                    crashes += 1;
                }
                Fate::Healthy => {}
            }
        }
        let drop_rate = drops as f64 / n as f64;
        assert!((drop_rate - 0.2).abs() < 0.04, "drop rate {drop_rate}");
        assert!(crashes > 0);
        // Faults off: always healthy.
        let off = sched(RoundPolicy::Sync, FaultConfig::default(), 1.0, 7);
        assert_eq!(off.fate(3, 5), Fate::Healthy);
    }

    #[test]
    fn sync_plan_admits_all_and_waits_for_the_slowest() {
        let mut s = sched(RoundPolicy::Sync, FaultConfig::default(), 1.0, 1);
        let plan = s.plan(&[(0, 4.0), (1, 9.5), (2, 1.0)]);
        assert_eq!(plan.decisions, vec![Decision::Admit; 3]);
        assert_eq!(plan.round_secs, 9.5);
        assert_eq!(plan.stragglers, 0);
        assert!(plan.ready.is_empty());
        // Zero arrivals degrade to a zero-length round.
        assert_eq!(s.plan(&[]).round_secs, 0.0);
    }

    #[test]
    fn deadline_plan_cuts_stragglers_and_degrades_gracefully() {
        let policy = RoundPolicy::SyncDeadline { deadline_secs: 5.0, over_select: 1.0 };
        let mut s = sched(policy, FaultConfig::default(), 1.0, 1);
        let plan = s.plan(&[(0, 2.0), (1, 8.0), (2, 4.0)]);
        assert_eq!(
            plan.decisions,
            vec![Decision::Admit, Decision::Straggle, Decision::Admit]
        );
        assert_eq!(plan.stragglers, 1);
        assert_eq!(plan.round_secs, 5.0, "cut-off rounds bill the full deadline");
        // All on time: the round ends when the last admitted lands.
        let early = s.plan(&[(0, 2.0), (1, 3.0)]);
        assert_eq!(early.round_secs, 3.0);
        // Nobody on time: zero admissions, still no panic, deadline billed.
        let none = s.plan(&[(0, 6.0), (1, 7.0)]);
        assert_eq!(none.decisions, vec![Decision::Straggle; 2]);
        assert_eq!(none.round_secs, 5.0);
    }

    #[test]
    fn async_plan_buffers_first_k_and_discounts_carries() {
        let policy = RoundPolicy::Async { buffer_k: 2, beta: 1.0, max_staleness: 10 };
        let mut s = sched(policy, FaultConfig::default(), 1.0, 1);
        // Round 0: three arrivals, K = 2 → fastest two admitted, slowest deferred.
        let plan = s.plan(&[(0, 4.0), (1, 1.0), (2, 2.0)]);
        assert_eq!(
            plan.decisions,
            vec![Decision::Defer, Decision::Admit, Decision::Admit]
        );
        assert_eq!(plan.round_secs, 2.0, "round ends at the K-th arrival");
        s.buffer_upload(0, vec![1.0, 1.0], 10.0);
        assert_eq!(s.buffered(), 1);
        s.end_round(true, plan.round_secs);
        assert_eq!(s.version(), 1);
        // Round 1: the carried upload (staleness 1) is first in line.
        let plan = s.plan(&[(3, 5.0)]);
        assert_eq!(plan.ready.len(), 1);
        assert_eq!(plan.ready[0].cid, 0);
        assert!((plan.ready[0].weight - 5.0).abs() < 1e-12, "10 * 1/(1+1)^1");
        assert_eq!(plan.decisions, vec![Decision::Admit]);
        assert_eq!(s.buffered(), 0);
    }

    #[test]
    fn async_k1_admits_only_the_earliest_event() {
        let policy = RoundPolicy::Async { buffer_k: 1, beta: 0.5, max_staleness: 10 };
        let mut s = sched(policy, FaultConfig::default(), 1.0, 1);
        let plan = s.plan(&[(0, 3.0), (1, 1.5)]);
        assert_eq!(plan.decisions, vec![Decision::Defer, Decision::Admit]);
        assert_eq!(plan.round_secs, 1.5);
        s.buffer_upload(0, vec![2.0], 1.0);
        s.end_round(true, plan.round_secs);
        // The carried upload beats a slow fresh client next round.
        let plan = s.plan(&[(2, 50.0)]);
        assert_eq!(plan.ready.len(), 1);
        assert_eq!(plan.decisions, vec![Decision::Defer]);
    }

    #[test]
    fn async_drops_over_stale_carries() {
        let policy = RoundPolicy::Async { buffer_k: 1, beta: 0.5, max_staleness: 1 };
        let faults = FaultConfig { retry_failed: true, ..Default::default() };
        let mut s = sched(policy, faults, 1.0, 1);
        let plan = s.plan(&[(7, 10.0), (8, 1.0)]);
        assert_eq!(plan.decisions, vec![Decision::Defer, Decision::Admit]);
        s.buffer_upload(7, vec![1.0], 1.0);
        s.end_round(true, plan.round_secs);
        // Two more aggregates land before cid 7's turn → staleness 2 > max 1.
        let plan = s.plan(&[(9, 0.5)]);
        assert_eq!(plan.dropped_cids, Vec::<usize>::new());
        s.end_round(true, plan.round_secs);
        let plan = s.plan(&[(10, 0.1)]);
        assert_eq!(plan.dropped_cids, vec![7]);
        assert_eq!(s.buffered(), 0);
        assert_eq!(s.take_retries(), vec![7], "dropped carries re-queue under retry");
    }

    #[test]
    fn arrival_times_compose_transfer_and_compute() {
        let s = sched(RoundPolicy::Sync, FaultConfig::default(), 1.0, 1);
        // Defaults: 10 Mbps up, 50 Mbps down, 1 Gflop/s, homogeneous.
        let t = s.arrival_secs(0, 1_000_000, 1_000_000, 2.0);
        let expected = (1e6 * 8.0) / 50e6 + 2.0 + (1e6 * 8.0) / 10e6;
        assert!((t - expected).abs() < 1e-12, "{t} vs {expected}");
    }
}
