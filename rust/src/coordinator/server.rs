//! The federated round loop — the L3 counterpart of paper Algorithms 1–2.
//!
//! A [`Federation`] owns the client population, the server model, the
//! optimizer state, and the communication ledger. Every round it samples
//! clients, ships them the global parameters (download), runs their local
//! epochs through the AOT train artifact, collects (optionally
//! fp16-quantized) uploads, and aggregates with the configured strategy.
//! Python never runs here — local training is one PJRT call per epoch.

use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::aggregate::{self, AdamState, FedDynState, ScaffoldState};
use super::client::ClientState;
use super::comm::{quantize_fp16, CommLedger};
use super::sampler::Sampler;
use crate::config::{Optimizer, RunConfig, Sharing};
use crate::data::{assemble_batches, Dataset};
use crate::parameterization::{Layout, SegmentKind};
use crate::runtime::{Engine, EvalOutput, ModelRuntime};
use crate::util::rng::Rng;

/// Per-round record (feeds every accuracy-vs-communication figure).
#[derive(Clone, Debug)]
pub struct RoundReport {
    pub round: usize,
    pub lr: f32,
    pub participants: usize,
    pub mean_train_loss: f64,
    pub up_bytes: u64,
    pub down_bytes: u64,
    pub cum_gbytes: f64,
    pub cum_energy_mj: f64,
    /// Global-model test accuracy, if evaluated this round.
    pub test_acc: Option<f64>,
    pub test_loss: Option<f64>,
    /// Measured local-compute wall time this round (seconds).
    pub t_comp_secs: f64,
}

/// Server-side optimizer state.
enum ServerOpt {
    Plain,
    Adam(AdamState),
    Scaffold(ScaffoldState),
    FedDyn(FedDynState),
}

/// A running federation.
pub struct Federation {
    pub cfg: RunConfig,
    rt: Rc<ModelRuntime>,
    /// Effective transfer layout (manifest layout with `Sharing` applied).
    layout: Layout,
    clients: Vec<ClientState>,
    test: Dataset,
    /// Full-length server parameter vector (local segments hold the common
    /// init, matching Algorithm 2's "transmit everything at start").
    server_params: Vec<f32>,
    opt: ServerOpt,
    pub comm: CommLedger,
    sampler: Sampler,
    root_rng: Rng,
    pub round: usize,
    pub reports: Vec<RoundReport>,
}

/// Apply a `Sharing` policy to the manifest layout.
pub fn effective_layout(base: &Layout, sharing: &Sharing) -> Layout {
    let mut l = base.clone();
    match sharing {
        Sharing::Full | Sharing::LocalOnly => {
            for s in l.segments.iter_mut() {
                s.kind = SegmentKind::Global;
            }
        }
        Sharing::GlobalSegments => {}
        Sharing::FedPer { local_prefixes } => {
            for s in l.segments.iter_mut() {
                s.kind = if local_prefixes.iter().any(|p| s.name.starts_with(p.as_str())) {
                    SegmentKind::Local
                } else {
                    SegmentKind::Global
                };
            }
        }
    }
    l
}

impl Federation {
    /// Build a federation over per-client datasets and a shared test set.
    pub fn new(
        engine: &Engine,
        cfg: RunConfig,
        locals: Vec<Dataset>,
        test: Dataset,
    ) -> Result<Federation> {
        if locals.is_empty() {
            return Err(anyhow!("no clients"));
        }
        let rt = engine.load(&cfg.artifact)?;
        let meta = &rt.meta;
        let layout = effective_layout(&meta.layout, &cfg.sharing);
        if matches!(cfg.optimizer, Optimizer::Scaffold | Optimizer::FedDyn { .. })
            && !matches!(cfg.sharing, Sharing::Full)
        {
            return Err(anyhow!(
                "SCAFFOLD/FedDyn require full sharing (control state spans all params)"
            ));
        }
        let mut root_rng = Rng::new(cfg.seed);
        let server_params = meta.layout.init_params(&mut root_rng);
        let clients: Vec<ClientState> = locals
            .into_iter()
            .map(|d| ClientState::new(d, server_params.clone()))
            .collect();
        let dim = meta.param_count;
        let opt = match cfg.optimizer {
            Optimizer::FedAvg | Optimizer::FedProx { .. } => ServerOpt::Plain,
            Optimizer::FedAdam => ServerOpt::Adam(AdamState::new(layout_global_len(&layout))),
            Optimizer::Scaffold => ServerOpt::Scaffold(ScaffoldState::new(dim, clients.len())),
            Optimizer::FedDyn { alpha } => {
                ServerOpt::FedDyn(FedDynState::new(dim, alpha as f64, clients.len()))
            }
        };
        let sampler = match cfg.sharing {
            Sharing::LocalOnly => Sampler::full(clients.len()),
            _ => Sampler::new(clients.len(), cfg.sample_frac, cfg.seed),
        };
        Ok(Federation {
            cfg,
            rt,
            layout,
            clients,
            test,
            server_params,
            opt,
            comm: CommLedger::new(),
            sampler,
            root_rng,
            round: 0,
            reports: Vec::new(),
        })
    }

    pub fn meta(&self) -> &crate::runtime::ArtifactMeta {
        &self.rt.meta
    }

    pub fn num_clients(&self) -> usize {
        self.clients.len()
    }

    /// Transferred bytes for one model download at this sharing policy.
    fn down_bytes(&self) -> u64 {
        (self.layout.global_len() * 4) as u64
    }

    /// Current learning rate (η·τ^round, Supp. C.4).
    pub fn current_lr(&self) -> f32 {
        (self.cfg.lr as f64 * self.cfg.lr_decay.powi(self.round as i32)) as f32
    }

    /// Run one federated round.
    pub fn run_round(&mut self) -> Result<RoundReport> {
        let lr = self.current_lr();
        let participants = self.sampler.sample(self.round);
        let local_only = matches!(self.cfg.sharing, Sharing::LocalOnly);
        let server_global = self.layout.gather_global(&self.server_params);
        let mut uploads: Vec<Vec<f32>> = Vec::with_capacity(participants.len());
        let mut weights: Vec<f64> = Vec::with_capacity(participants.len());
        let mut delta_controls: Vec<Vec<f32>> = Vec::new();
        let mut full_models: Vec<Vec<f32>> = Vec::new();
        let mut loss_acc = 0.0f64;
        let t_comp_start = Instant::now();

        let t = self.rt.meta.train;
        let steps_per_round = (self.cfg.local_epochs * t.nbatches) as f32;

        for &cid in &participants {
            // ---- download ------------------------------------------------
            if !local_only {
                self.layout
                    .scatter_global(&mut self.clients[cid].params, &server_global);
                self.comm.record_download(self.down_bytes());
                if matches!(self.cfg.optimizer, Optimizer::Scaffold) {
                    // Server control variate rides along with the model.
                    self.comm.record_download((self.rt.meta.param_count * 4) as u64);
                }
            }
            let anchor = self.clients[cid].params.clone();

            // Optimizer-specific extra inputs.
            let (correction, anchor_opt, mu): (Option<Vec<f32>>, Option<&[f32]>, f32) =
                match &self.cfg.optimizer {
                    Optimizer::FedAvg | Optimizer::FedAdam => (None, None, 0.0),
                    Optimizer::FedProx { mu } => (None, Some(&anchor), *mu),
                    Optimizer::Scaffold => {
                        let c_global = match &self.opt {
                            ServerOpt::Scaffold(s) => s.c.clone(),
                            _ => unreachable!(),
                        };
                        let c_i = self.clients[cid]
                            .control
                            .get_or_insert_with(|| vec![0.0; c_global.len()])
                            .clone();
                        (Some(aggregate::sub(&c_global, &c_i)), None, 0.0)
                    }
                    Optimizer::FedDyn { alpha } => {
                        let lam = self.clients[cid]
                            .lambda
                            .get_or_insert_with(|| vec![0.0; anchor.len()])
                            .clone();
                        let neg: Vec<f32> = lam.iter().map(|&x| -x).collect();
                        (Some(neg), Some(&anchor), *alpha)
                    }
                };

            // ---- local training -------------------------------------------
            let mut params = self.clients[cid].params.clone();
            let mut rng = self.root_rng.child((self.round as u64) << 20 | cid as u64);
            let idx: Vec<usize> = (0..self.clients[cid].data.len()).collect();
            for _epoch in 0..self.cfg.local_epochs {
                let stack =
                    assemble_batches(&self.clients[cid].data, &idx, t.nbatches, t.batch, &mut rng);
                let out = self.rt.train_epoch(
                    &params,
                    &stack.x,
                    &stack.y,
                    lr,
                    correction.as_deref(),
                    anchor_opt,
                    mu,
                )?;
                params = out.params;
                loss_acc += out.mean_loss as f64;
            }

            // ---- client state updates -------------------------------------
            match self.cfg.optimizer {
                Optimizer::Scaffold => {
                    // Option II: c_i⁺ = c_i − c + (x − y_i)/(K·η).
                    let c_global = match &self.opt {
                        ServerOpt::Scaffold(s) => s.c.clone(),
                        _ => unreachable!(),
                    };
                    let c_i = self.clients[cid].control.as_mut().unwrap();
                    let scale = 1.0 / (steps_per_round * lr);
                    let mut new_c = Vec::with_capacity(c_i.len());
                    let mut delta_c = Vec::with_capacity(c_i.len());
                    for j in 0..c_i.len() {
                        let v = c_i[j] - c_global[j] + scale * (anchor[j] - params[j]);
                        delta_c.push(v - c_i[j]);
                        new_c.push(v);
                    }
                    *c_i = new_c;
                    delta_controls.push(delta_c);
                }
                Optimizer::FedDyn { alpha } => {
                    let lam = self.clients[cid].lambda.as_mut().unwrap();
                    for j in 0..lam.len() {
                        lam[j] -= alpha * (params[j] - anchor[j]);
                    }
                }
                _ => {}
            }
            self.clients[cid].params = params;
            self.clients[cid].participations += 1;

            // ---- upload ---------------------------------------------------
            if !local_only {
                let mut up = self.layout.gather_global(&self.clients[cid].params);
                let bytes = if self.cfg.quantize_upload {
                    let (deq, b) = quantize_fp16(&up);
                    up = deq;
                    b
                } else {
                    (up.len() * 4) as u64
                };
                self.comm.record_upload(bytes);
                if matches!(self.cfg.optimizer, Optimizer::Scaffold) {
                    self.comm.record_upload((self.rt.meta.param_count * 4) as u64);
                }
                if matches!(self.cfg.optimizer, Optimizer::FedDyn { .. } | Optimizer::Scaffold) {
                    full_models.push(self.clients[cid].params.clone());
                }
                uploads.push(up);
                weights.push(self.clients[cid].num_samples() as f64);
            }
        }
        let t_comp = t_comp_start.elapsed().as_secs_f64();

        // ---- aggregation ---------------------------------------------------
        if !local_only {
            let new_global = match &mut self.opt {
                ServerOpt::Plain => aggregate::weighted_mean(&uploads, &weights),
                ServerOpt::Adam(adam) => adam.step(
                    &server_global,
                    &aggregate::weighted_mean(&uploads, &weights),
                ),
                ServerOpt::Scaffold(sc) => {
                    let deltas: Vec<Vec<f32>> = full_models
                        .iter()
                        .map(|m| aggregate::sub(m, &self.server_params))
                        .collect();
                    let new_full = sc.step(&self.server_params, &deltas, &delta_controls);
                    self.server_params = new_full;
                    self.layout.gather_global(&self.server_params)
                }
                ServerOpt::FedDyn(fd) => {
                    let new_full = fd.step(&self.server_params, &full_models);
                    self.server_params = new_full;
                    self.layout.gather_global(&self.server_params)
                }
            };
            self.layout.scatter_global(&mut self.server_params, &new_global);
        }
        self.comm.end_round();

        // ---- report ---------------------------------------------------------
        let evaluate = self.cfg.eval_every > 0 && (self.round + 1) % self.cfg.eval_every == 0;
        let (test_acc, test_loss) = if evaluate && !local_only {
            let e = self.evaluate_global()?;
            (Some(e.accuracy()), Some(e.mean_loss()))
        } else {
            (None, None)
        };
        let (up, down) = *self.comm.per_round.last().unwrap();
        let report = RoundReport {
            round: self.round,
            lr,
            participants: participants.len(),
            mean_train_loss: loss_acc
                / (participants.len().max(1) * self.cfg.local_epochs) as f64,
            up_bytes: up,
            down_bytes: down,
            cum_gbytes: self.comm.total_gbytes(),
            cum_energy_mj: self.comm.total_energy_mj(),
            test_acc,
            test_loss,
            t_comp_secs: t_comp,
        };
        self.round += 1;
        self.reports.push(report.clone());
        Ok(report)
    }

    /// Run `rounds` rounds, returning the reports.
    pub fn run(&mut self, rounds: usize) -> Result<&[RoundReport]> {
        for _ in 0..rounds {
            let r = self.run_round()?;
            if crate::util::logging::enabled(crate::util::logging::Level::Info) {
                let acc = r.test_acc.map(|a| format!("{:.2}%", a * 100.0)).unwrap_or_default();
                crate::log_info!(
                    "round {:>4}  loss {:.4}  lr {:.4}  cum {:.4} GB  {}",
                    r.round,
                    r.mean_train_loss,
                    r.lr,
                    r.cum_gbytes,
                    acc
                );
            }
        }
        Ok(&self.reports)
    }

    /// Evaluate the current global model on the shared test set.
    pub fn evaluate_global(&self) -> Result<EvalOutput> {
        eval_on(&self.rt, &self.server_params, &self.test)
    }

    /// Evaluate each client's *personalized* model (its full parameter
    /// vector, local segments included) on its own test set — the Figure-5
    /// protocol. Returns per-client accuracies.
    pub fn evaluate_personalized(&self, client_tests: &[Dataset]) -> Result<Vec<f64>> {
        if client_tests.len() != self.clients.len() {
            return Err(anyhow!("need one test set per client"));
        }
        let mut accs = Vec::with_capacity(self.clients.len());
        for (c, t) in self.clients.iter().zip(client_tests) {
            // A client that never trained evaluates its init — fine.
            let mut params = c.params.clone();
            if !matches!(self.cfg.sharing, Sharing::LocalOnly) {
                // Personalized model = latest global + own local segments.
                let g = self.layout.gather_global(&self.server_params);
                self.layout.scatter_global(&mut params, &g);
            }
            accs.push(eval_on(&self.rt, &params, t)?.accuracy());
        }
        Ok(accs)
    }

    /// Snapshot of the server model (global vector view).
    pub fn server_global(&self) -> Vec<f32> {
        self.layout.gather_global(&self.server_params)
    }
}

fn layout_global_len(l: &Layout) -> usize {
    l.global_len()
}

/// Evaluate `params` on a whole dataset by chunking it through the fixed
/// eval shape (the final chunk wraps around; with test sizes that are
/// multiples of the eval call size there is no double counting).
pub fn eval_on(rt: &ModelRuntime, params: &[f32], data: &Dataset) -> Result<EvalOutput> {
    let e = rt.meta.eval;
    let need = e.nbatches * e.batch;
    let mut merged: Option<EvalOutput> = None;
    let mut start = 0usize;
    while start < data.len() {
        let idx: Vec<usize> = (start..start + need).map(|i| i % data.len()).collect();
        let sub = data.subset(&idx);
        let mut x = Vec::with_capacity(need * data.feature_dim);
        let mut y = Vec::with_capacity(need);
        for i in 0..need {
            let (f, l) = sub.sample(i);
            x.extend_from_slice(f);
            y.push(l as f32);
        }
        let out = rt.eval_call(params, &x, &y)?;
        match merged.as_mut() {
            Some(m) => m.merge(&out),
            None => merged = Some(out),
        }
        start += need;
    }
    merged.ok_or_else(|| anyhow!("empty test set"))
}
