//! The federated round loop — the L3 counterpart of paper Algorithms 1–2.
//!
//! A [`Federation`] owns the client population (through a sparse, lazy
//! [`ClientStore`]), the server model, the optimizer state, and the
//! communication ledger. Every round it samples clients and fans one pure
//! [`LocalTrainJob`] per participant out over a [`ThreadPool`]: each job
//! downloads a parameter snapshot, runs its local epochs through the
//! (Arc-shared, `Send + Sync`) [`ModelRuntime`], and returns its upload,
//! its optimizer side-state, and a [`CommDelta`]. The reduce side folds
//! outcomes **in participant order** on the coordinator thread — uploads
//! stream into a [`WeightedAccumulator`] and are dropped as soon as they
//! are folded, so aggregation typically holds `O(dim)` state rather than
//! materializing every upload. (Peak memory is still `O(participants ×
//! dim)`: job parameter snapshots are materialized at fan-out, and
//! out-of-order outcomes buffer until their fold turn — the win over
//! collect-then-aggregate is the streaming drop of uploads, not an
//! asymptotic bound.) The fixed fold order makes every ledger byte, loss,
//! and server parameter bit-identical across pool sizes (client RNG
//! streams are keyed by `(round, cid)`, never by worker).
//!
//! **Cross-device scale.** Round cost is O(participants), never
//! O(population): participant datasets and parameter snapshots are
//! materialized per round from the store and dropped at fold time, and
//! per-client persistent state is instantiated sparsely on first
//! participation (see [`ClientStore`]). [`Federation::new_virtual`] runs a
//! population of millions of virtual clients in constant memory per
//! round; `tests/store_equivalence.rs` pins it bit-identical to the eager
//! construction at the paper's 100-client configs.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::aggregate::{self, AdamState, FedDynState, ScaffoldState, WeightedAccumulator};
use super::comm::{CommDelta, CommLedger};
use super::sampler::Sampler;
use super::sched::{Decision, Fate, Scheduler};
use super::store::{ClientDataSource, ClientStore, RoundData};
use super::wire::{self, Downlink, WireCodec, FINGERPRINT_BYTES};
use crate::config::{DeviceClasses, Optimizer, RoundPolicy, RunConfig, Sharing};
use crate::data::{assemble_batches_into, BatchStack, Dataset};
use crate::parameterization::{Layout, SegmentKind};
use crate::runtime::{Engine, EvalOutput, GemmBackend, ModelRuntime, Workspace};
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;

/// Per-round record (feeds every accuracy-vs-communication figure).
#[derive(Clone, Debug)]
pub struct RoundReport {
    pub round: usize,
    pub lr: f32,
    pub participants: usize,
    pub mean_train_loss: f64,
    pub up_bytes: u64,
    pub down_bytes: u64,
    pub cum_gbytes: f64,
    pub cum_energy_mj: f64,
    /// Global-model test accuracy, if evaluated this round.
    pub test_acc: Option<f64>,
    pub test_loss: Option<f64>,
    /// Measured local-compute wall time this round (seconds).
    pub t_comp_secs: f64,
    /// Simulated seconds this round occupied on the scheduler's virtual
    /// event clock (analytic — thread-count invariant, never host time).
    pub t_sim_secs: f64,
    /// Sampled clients that trained but missed the aggregation deadline.
    pub stragglers: usize,
    /// Sampled clients lost to fault injection (dropout/crash) plus async
    /// buffered updates discarded as over-stale.
    pub dropped: usize,
}

/// Server-side optimizer state.
enum ServerOpt {
    Plain,
    Adam(AdamState),
    Scaffold(ScaffoldState),
    FedDyn(FedDynState),
}

/// A running federation.
pub struct Federation {
    pub cfg: RunConfig,
    rt: Arc<ModelRuntime>,
    /// Effective transfer layout (manifest layout with `Sharing` applied).
    layout: Arc<Layout>,
    /// Sparse, lazy client population (datasets + persistent state).
    store: ClientStore,
    test: Dataset,
    /// Full-length server parameter vector (local segments hold the common
    /// init, matching Algorithm 2's "transmit everything at start").
    server_params: Vec<f32>,
    opt: ServerOpt,
    pub comm: CommLedger,
    sampler: Sampler,
    /// Virtual-time round scheduler: fault fates, arrival times, and the
    /// policy's admission plan (sync barrier / deadline cut / async buffer).
    sched: Scheduler,
    /// Heterogeneous-device fleet (rank truncation masks + slowdowns);
    /// `None` for the homogeneous default — that path is bit-identical to
    /// the pre-elasticity coordinator (`tests/hetero_equivalence.rs`).
    fleet: Option<DeviceFleet>,
    root_rng: Rng,
    /// Uplink wire codec (shared by every job; stateless — per-client
    /// error-feedback accumulators live in the store).
    up_codec: Arc<dyn WireCodec>,
    /// Server→client wire state: down codec + fingerprint cache.
    downlink: Downlink,
    /// Shared (`Arc` so eval workspaces can borrow it for intra-op
    /// row-blocked GEMMs while the fan-out is idle).
    pool: Arc<ThreadPool>,
    /// GEMM backend every scratch workspace (training jobs **and** eval)
    /// routes through — one knob, no per-path asymmetry.
    gemm_backend: GemmBackend,
    /// Reusable per-job scratch, one entry per in-flight client job,
    /// returned to the pool at fold time — so steady-state rounds run the
    /// whole local-training hot path without heap allocation.
    scratch_pool: Vec<JobScratch>,
    /// Cached evaluation scratch (pool attached for row-blocked forward
    /// GEMMs), shared by `evaluate_global`/`evaluate_personalized` so
    /// per-round evaluation stays off the allocator too. Behind a `Mutex`
    /// only because evaluation takes `&self`; it is used exclusively from
    /// the coordinator thread while the fan-out pool is idle.
    eval_scratch: Mutex<EvalScratch>,
    pub round: usize,
    pub reports: Vec<RoundReport>,
}

/// Per-job reusable scratch: the runtime workspace (activations, composed
/// weights, gradients, …) plus the assembled batch stack.
struct JobScratch {
    ws: Workspace,
    stack: BatchStack,
}

impl JobScratch {
    /// Job workspaces run *inside* pool jobs, so they never attach the
    /// pool themselves (`ThreadPool::run_borrowed` must not be re-entered)
    /// — but they do take the federation's backend choice, so training and
    /// eval can never disagree about which GEMM path executes.
    fn new(rt: &ModelRuntime, backend: GemmBackend) -> JobScratch {
        let mut ws = rt.workspace();
        ws.set_backend(backend);
        JobScratch {
            ws,
            stack: BatchStack { x: Vec::new(), y: Vec::new(), nbatches: 0, batch: 0, feature_dim: 0 },
        }
    }
}

/// Resolved heterogeneous-device fleet (FedHM-style rank elasticity):
/// per-class truncation masks over the *global* coordinate space plus the
/// deterministic per-client class assignment. Built once at federation
/// construction; absent (`None` on [`Federation`]) for the homogeneous
/// default, so that path carries zero extra state.
struct DeviceFleet {
    classes: DeviceClasses,
    seed: u64,
    /// Per device class: `None` for full-rank classes, else the active-
    /// coordinate mask (`false` at truncated factor columns / Tucker
    /// blocks) and its active count — the billed wire length. Truncation
    /// requires `Sharing::Full`, so global coordinates == full vector.
    masks: Vec<Option<(Arc<Vec<bool>>, usize)>>,
}

impl DeviceFleet {
    /// This client's truncation mask (`None` ⇒ full rank).
    fn mask_for(&self, cid: usize) -> Option<&(Arc<Vec<bool>>, usize)> {
        self.masks[self.classes.class_of(self.seed, cid)].as_ref()
    }

    /// This client's compute slowdown multiplier (≥ 1).
    fn slowdown(&self, cid: usize) -> f64 {
        self.classes.class_for(self.seed, cid).slowdown
    }
}

/// Apply a `Sharing` policy to the manifest layout.
pub fn effective_layout(base: &Layout, sharing: &Sharing) -> Layout {
    let mut l = base.clone();
    match sharing {
        Sharing::Full | Sharing::LocalOnly => {
            for s in l.segments.iter_mut() {
                s.kind = SegmentKind::Global;
            }
        }
        Sharing::GlobalSegments => {}
        Sharing::FedPer { local_prefixes } => {
            for s in l.segments.iter_mut() {
                s.kind = if local_prefixes.iter().any(|p| s.name.starts_with(p.as_str())) {
                    SegmentKind::Local
                } else {
                    SegmentKind::Global
                };
            }
        }
    }
    l
}

/// Optimizer-specific inputs one local-training job carries.
enum JobOpt {
    Plain,
    Prox { mu: f32 },
    Scaffold {
        c_global: Arc<Vec<f32>>,
        c_i: Vec<f32>,
        /// `1 / (K·η)` for the Option-II control update.
        inv_k_eta: f32,
    },
    FedDyn { alpha: f32, lambda: Vec<f32> },
}

/// One participant's work for one round: download snapshot → local epochs →
/// upload + optimizer side-state. Pure (owns or `Arc`-shares every input),
/// so any worker thread can run it.
struct LocalTrainJob {
    cid: usize,
    rt: Arc<ModelRuntime>,
    layout: Arc<Layout>,
    /// Dataset handle — deferred for virtual populations, so the
    /// O(per_client) synthesis runs on the worker, not the coordinator.
    data: RoundData,
    /// The client's full parameter vector as of the previous round; the
    /// job applies the download itself so a failed round leaves client
    /// state untouched.
    params: Vec<f32>,
    /// Server global snapshot to scatter in on download (`None` when
    /// local-only — nothing is transferred).
    download: Option<Arc<Vec<f32>>>,
    /// Client RNG stream, keyed by `(round, cid)` — pool-size independent.
    rng: Rng,
    lr: f32,
    local_epochs: usize,
    opt: JobOpt,
    /// Uplink wire codec: the upload (and any side-state riding it) is
    /// transformed and billed through this seam.
    up: Arc<dyn WireCodec>,
    /// Per-client error-feedback accumulator, present iff the up codec
    /// uses feedback; carried by the job (not shared) so parallel
    /// scheduling cannot reorder its updates, and persisted back through
    /// the outcome.
    feedback: Option<Vec<f32>>,
    /// Device-class rank-truncation mask (`None` = full rank). Applied to
    /// the post-download parameters: zeroed factor columns/Tucker blocks
    /// have identically zero gradients through the Hadamard product, so
    /// training runs exactly the truncated factorization with no kernel
    /// changes and no new allocation.
    rank_mask: Option<Arc<Vec<bool>>>,
    /// Billed uplink value count for truncated clients — the coordinates
    /// inside the rank budget (`None` bills the full wire length).
    billed_up_len: Option<usize>,
    local_only: bool,
    /// Download bytes recorded at job construction.
    comm: CommDelta,
    /// Pooled scratch (workspace + batch stack), owned for the duration of
    /// the job and handed back through the outcome for reuse next round.
    scratch: JobScratch,
}

/// What a job hands back to the reduce.
struct LocalTrainOutcome {
    cid: usize,
    /// Client's full parameter vector after local training.
    params: Vec<f32>,
    /// The global vector the server receives (dequantized wire values);
    /// empty when local-only.
    upload: Vec<f32>,
    /// Sum of per-epoch mean losses, in epoch order.
    loss_sum: f64,
    weight: f64,
    comm: CommDelta,
    /// SCAFFOLD: updated client control and its (wire) delta.
    new_control: Option<Vec<f32>>,
    delta_control: Option<Vec<f32>>,
    /// FedDyn: updated client λ state.
    new_lambda: Option<Vec<f32>>,
    /// Updated error-feedback accumulator (returned to the store).
    feedback: Option<Vec<f32>>,
    /// The client's truncation mask, passed through for the masked
    /// aggregation fold.
    rank_mask: Option<Arc<Vec<bool>>>,
    /// The job's scratch, returned to the federation's pool.
    scratch: JobScratch,
}

impl LocalTrainJob {
    fn run(self) -> Result<LocalTrainOutcome> {
        let LocalTrainJob {
            cid,
            rt,
            layout,
            data,
            params,
            download,
            mut rng,
            lr,
            local_epochs,
            opt,
            up,
            mut feedback,
            rank_mask,
            billed_up_len,
            local_only,
            mut comm,
            mut scratch,
        } = self;
        // Deferred (virtual) datasets synthesize here, on the worker; the
        // aggregation weight is the materialized sample count either way.
        let data = data.materialize();
        let weight = data.len() as f64;
        let t = rt.meta.train;
        // ---- download -----------------------------------------------------
        let mut p = params;
        if let Some(g) = &download {
            layout.scatter_global(&mut p, g);
        }
        // Rank truncation: zero the factor coordinates outside this
        // device's budget *before* the optimizer anchor snapshot, so the
        // FedProx/FedDyn proximal pull can't repopulate them. From here
        // the run is exactly the truncated factorization — the composed
        // weight equals the truncated composition, and every masked
        // coordinate's gradient is identically zero (each factor column's
        // gradient is linear in the matching column of its partner
        // factor, which is also zeroed), so SGD holds them at 0.
        if let Some(mask) = &rank_mask {
            for (v, &keep) in p.iter_mut().zip(mask.iter()) {
                if !keep {
                    *v = 0.0;
                }
            }
        }
        // FedProx/FedDyn anchor and SCAFFOLD's control update need the
        // post-download snapshot; plain FedAvg/FedAdam skip the clone.
        let start = if matches!(opt, JobOpt::Plain) { Vec::new() } else { p.clone() };
        let correction: Option<Vec<f32>> = match &opt {
            JobOpt::Scaffold { c_global, c_i, .. } => Some(aggregate::sub(c_global, c_i)),
            JobOpt::FedDyn { lambda, .. } => Some(lambda.iter().map(|&x| -x).collect()),
            _ => None,
        };
        let (use_anchor, mu) = match &opt {
            JobOpt::Prox { mu } => (true, *mu),
            JobOpt::FedDyn { alpha, .. } => (true, *alpha),
            _ => (false, 0.0),
        };
        let anchor = if use_anchor { Some(start.as_slice()) } else { None };

        // ---- local training -----------------------------------------------
        // In-place epochs through the pooled workspace + batch stack: the
        // steady-state loop (same client sizes round over round) performs
        // no heap allocation beyond the small per-epoch index shuffles.
        let mut loss_sum = 0.0f64;
        let idx: Vec<usize> = (0..data.len()).collect();
        for _epoch in 0..local_epochs {
            assemble_batches_into(&mut scratch.stack, &data, &idx, t.nbatches, t.batch, &mut rng);
            let mean_loss = rt.train_epoch_ws(
                &mut scratch.ws,
                &mut p,
                &scratch.stack.x,
                &scratch.stack.y,
                lr,
                correction.as_deref(),
                anchor,
                mu,
            )?;
            loss_sum += mean_loss as f64;
        }

        // ---- optimizer side-state -----------------------------------------
        let (new_control, mut delta_control, new_lambda) = match opt {
            JobOpt::Scaffold { c_global, c_i, inv_k_eta } => {
                // Option II: c_i⁺ = c_i − c + (x − y_i)/(K·η).
                let mut new_c = Vec::with_capacity(c_i.len());
                let mut delta_c = Vec::with_capacity(c_i.len());
                for j in 0..c_i.len() {
                    let v = c_i[j] - c_global[j] + inv_k_eta * (start[j] - p[j]);
                    delta_c.push(v - c_i[j]);
                    new_c.push(v);
                }
                (Some(new_c), Some(delta_c), None)
            }
            JobOpt::FedDyn { alpha, mut lambda } => {
                for j in 0..lambda.len() {
                    lambda[j] -= alpha * (p[j] - start[j]);
                }
                (None, None, Some(lambda))
            }
            JobOpt::Plain | JobOpt::Prox { .. } => (None, None, None),
        };

        // ---- upload -------------------------------------------------------
        let mut upload = Vec::new();
        if !local_only {
            let mut gathered = layout.gather_global(&p);
            // Sketch codecs delta-code against the wire global this client
            // just received; dense codecs ignore the reference. The codec
            // draws from the job's own rng *after* training consumed its
            // fixed-length stream, so wire randomness is keyed by
            // (round, cid) and pool-size invariant like everything else.
            let reference = download.as_ref().map(|g| g.as_slice());
            let bytes = up.transmit(&mut gathered, reference, feedback.as_mut(), &mut rng);
            // Truncated clients only put their in-budget coordinates on
            // the wire (the rest are structural zeros the server already
            // knows about), so they are billed at the truncated length.
            let bytes = match billed_up_len {
                Some(len) => up.billed_bytes(len),
                None => bytes,
            };
            comm.record_upload(bytes);
            if let Some(mut dc) = delta_control.take() {
                // The SCAFFOLD control variate rides the same uplink codec
                // as the model (it is already a delta, with no feedback
                // state of its own), so compressed uploads don't get
                // billed at fp32.
                let b = up.transmit(&mut dc, None, None, &mut rng);
                comm.record_upload(b);
                delta_control = Some(dc);
            }
            upload = gathered;
        }

        Ok(LocalTrainOutcome {
            cid,
            params: p,
            upload,
            loss_sum,
            weight,
            comm,
            new_control,
            delta_control,
            new_lambda,
            feedback,
            rank_mask,
            scratch,
        })
    }
}

impl Federation {
    /// Build a federation over per-client datasets and a shared test set
    /// (the classic eager/cross-silo construction).
    pub fn new(
        engine: &Engine,
        cfg: RunConfig,
        locals: Vec<Dataset>,
        test: Dataset,
    ) -> Result<Federation> {
        Federation::new_virtual(engine, cfg, ClientDataSource::eager(locals), test)
    }

    /// Build a federation over any [`ClientDataSource`] — including a
    /// *virtual* population of millions of clients whose datasets are
    /// synthesized deterministically on demand. Construction cost is
    /// O(param_count), independent of population; an eager source makes
    /// this identical to [`Federation::new`].
    pub fn new_virtual(
        engine: &Engine,
        cfg: RunConfig,
        source: ClientDataSource,
        test: Dataset,
    ) -> Result<Federation> {
        let population = source.population();
        if population == 0 {
            return Err(anyhow!("no clients"));
        }
        let rt = engine.load(&cfg.artifact)?;
        let meta = &rt.meta;
        let layout = Arc::new(effective_layout(&meta.layout, &cfg.sharing));
        if matches!(cfg.optimizer, Optimizer::Scaffold | Optimizer::FedDyn { .. })
            && !matches!(cfg.sharing, Sharing::Full)
        {
            return Err(anyhow!(
                "SCAFFOLD/FedDyn require full sharing (control state spans all params)"
            ));
        }
        cfg.wire.validate().map_err(|e| anyhow!("invalid wire config: {e}"))?;
        cfg.sched.validate().map_err(|e| anyhow!("invalid sched config: {e}"))?;
        cfg.sched.check_optimizer(&cfg.optimizer).map_err(|e| anyhow!("{e}"))?;
        cfg.devices.validate().map_err(|e| anyhow!("invalid device classes: {e}"))?;
        cfg.devices.check_optimizer(&cfg.optimizer).map_err(|e| anyhow!("{e}"))?;
        cfg.devices.check_wire(&cfg.wire).map_err(|e| anyhow!("{e}"))?;
        let fleet = if cfg.devices.enabled() {
            let mut masks: Vec<Option<(Arc<Vec<bool>>, usize)>> =
                vec![None; cfg.devices.classes.len()];
            if cfg.devices.truncates() {
                if !matches!(cfg.sharing, Sharing::Full) {
                    return Err(anyhow!(
                        "device rank truncation requires full sharing — the factor masks \
                         span the whole parameter vector"
                    ));
                }
                let map = rt.rank_map().ok_or_else(|| {
                    anyhow!(
                        "device rank truncation needs the native backend; AOT artifacts \
                         bake full-rank shapes into their compiled programs"
                    )
                })?;
                if map.blocks.is_empty() {
                    return Err(anyhow!(
                        "artifact '{}' has no low-rank factor segments to truncate; use a \
                         fedpara/lowrank artifact or a full-rank device fleet",
                        cfg.artifact
                    ));
                }
                for (slot, class) in masks.iter_mut().zip(&cfg.devices.classes) {
                    if !map.truncates_at(class.rank_frac) {
                        continue;
                    }
                    // The mask is the rank truncation applied to a ones
                    // vector: exactly the coordinates the masked client
                    // can represent survive.
                    let mut ones = vec![1.0f32; meta.param_count];
                    map.mask(&mut ones, class.rank_frac);
                    let active: Vec<bool> = ones.iter().map(|&x| x != 0.0).collect();
                    let active_len = active.iter().filter(|&&b| b).count();
                    *slot = Some((Arc::new(active), active_len));
                }
            }
            Some(DeviceFleet { classes: cfg.devices.clone(), seed: cfg.seed, masks })
        } else {
            None
        };
        let up_codec = wire::codec_for(&cfg.wire.up);
        let downlink = Downlink::new(&cfg.wire.down, cfg.wire.fingerprint_downloads, cfg.seed);
        let mut root_rng = Rng::new(cfg.seed);
        let server_params = meta.layout.init_params(&mut root_rng);
        let local_only = matches!(cfg.sharing, Sharing::LocalOnly);
        let mut store = ClientStore::new(
            source,
            Arc::clone(&layout),
            Arc::new(server_params.clone()),
            local_only,
        );
        if cfg.wire.fingerprint_downloads {
            // Every virtual client implicitly holds the shared init
            // (Algorithm 2's "transmit everything at start"), so the
            // fingerprint cache starts primed with the init global's hash:
            // an untouched client asked to download a global that is still
            // bit-identical to the init pays only the hash check.
            store.set_init_global_hash(wire::global_fingerprint(
                &layout.gather_global(&server_params),
            ));
        }
        let dim = meta.param_count;
        let opt = match cfg.optimizer {
            Optimizer::FedAvg | Optimizer::FedProx { .. } => ServerOpt::Plain,
            Optimizer::FedAdam => ServerOpt::Adam(AdamState::new(layout.global_len())),
            Optimizer::Scaffold => ServerOpt::Scaffold(ScaffoldState::new(dim, population)),
            Optimizer::FedDyn { alpha } => {
                ServerOpt::FedDyn(FedDynState::new(dim, alpha as f64, population))
            }
        };
        let sampler = match cfg.sharing {
            Sharing::LocalOnly => Sampler::full(population),
            _ => Sampler::new(population, cfg.sample_frac, cfg.seed),
        };
        // A round never has more jobs in flight than clients, so don't
        // spawn (and later join) workers that could never be used.
        let requested = match cfg.num_threads {
            0 => ThreadPool::host_parallelism(),
            n => n,
        };
        let pool = Arc::new(ThreadPool::new(requested.min(population)));
        let gemm_backend = GemmBackend::default();
        // Evaluation runs on the coordinator thread while the fan-out is
        // idle, so its workspace can safely borrow the pool for intra-op
        // row-blocked GEMMs. It shares the training jobs' backend choice —
        // the two paths route through the same `GemmCtx` by construction.
        let mut eval_ws = EvalScratch::new(&rt);
        eval_ws.set_pool(Some(Arc::clone(&pool)));
        eval_ws.set_backend(gemm_backend);
        let sched = Scheduler::new(cfg.sched, cfg.seed);
        Ok(Federation {
            cfg,
            rt,
            layout,
            store,
            test,
            server_params,
            opt,
            comm: CommLedger::new(),
            sampler,
            sched,
            fleet,
            root_rng,
            up_codec,
            downlink,
            pool,
            gemm_backend,
            scratch_pool: Vec::new(),
            eval_scratch: Mutex::new(eval_ws),
            round: 0,
            reports: Vec::new(),
        })
    }

    pub fn meta(&self) -> &crate::runtime::ArtifactMeta {
        &self.rt.meta
    }

    /// Select the GEMM backend for **all** federation compute — pooled job
    /// scratch (training) and the cached eval scratch alike. Replaces the
    /// old process-global `force_naive` toggle: the choice is per
    /// federation, applied to already-pooled workspaces immediately, and
    /// carried into every scratch allocated later.
    pub fn set_gemm_backend(&mut self, backend: GemmBackend) {
        self.gemm_backend = backend;
        for scratch in self.scratch_pool.iter_mut() {
            scratch.ws.set_backend(backend);
        }
        self.eval_scratch.lock().expect("eval workspace lock poisoned").set_backend(backend);
    }

    pub fn num_clients(&self) -> usize {
        self.store.population()
    }

    /// The sparse client store (population, touched set, live-state
    /// accounting).
    pub fn store(&self) -> &ClientStore {
        &self.store
    }

    /// Bytes of live per-client state held by the store right now — the
    /// cross-device memory invariant: O(participants + touched), never
    /// O(population). See [`ClientStore::live_state_bytes`].
    pub fn live_state_bytes(&self) -> usize {
        self.store.live_state_bytes()
    }

    /// Worker threads serving the per-round client fan-out.
    pub fn pool_size(&self) -> usize {
        self.pool.size()
    }

    /// Current learning rate (η·τ^round, Supp. C.4).
    pub fn current_lr(&self) -> f32 {
        (self.cfg.lr as f64 * self.cfg.lr_decay.powi(self.round as i32)) as f32
    }

    /// This round's cohort under the scheduler's policy. `Sync` is the
    /// historical sampler draw, bit for bit. `SyncDeadline` over-selects
    /// (Bonawitz et al. 2019) so deadline losses don't starve the round.
    /// `Async` draws normally but skips clients whose previous upload is
    /// still buffered server-side. Failed clients from earlier rounds are
    /// merged back in when the fault model retries them.
    fn select_participants(&mut self) -> Vec<usize> {
        let mut ids = match self.sched.policy() {
            RoundPolicy::Sync | RoundPolicy::Async { .. } => self.sampler.sample(self.round),
            RoundPolicy::SyncDeadline { over_select, .. } => {
                let k = (self.sampler.per_round() as f64 * over_select).ceil() as usize;
                self.sampler.sample_n(self.round, k)
            }
        };
        let retries = self.sched.take_retries();
        if !retries.is_empty() {
            ids.extend(retries);
            ids.sort_unstable();
            ids.dedup();
        }
        if matches!(self.sched.policy(), RoundPolicy::Async { .. }) {
            let store = &self.store;
            ids.retain(|&cid| !store.in_flight(cid));
        }
        ids
    }

    /// Run one federated round.
    pub fn run_round(&mut self) -> Result<RoundReport> {
        let lr = self.current_lr();
        let participants = self.select_participants();
        let local_only = matches!(self.cfg.sharing, Sharing::LocalOnly);
        // The raw global feeds the FedAdam server step below; what clients
        // download is the *wire* global — encoded once per round by the
        // downlink codec (every participant receives the same broadcast)
        // and fingerprinted for the redelivery cache. Under the identity
        // codec the broadcast is the raw Arc itself: zero copies, zero rng
        // draws, bit-identical to the pre-codec path.
        let server_global = Arc::new(self.layout.gather_global(&self.server_params));
        let (wire_global, down_model_bytes, wire_hash) = if local_only {
            (Arc::clone(&server_global), 0, None)
        } else {
            self.downlink.broadcast(&server_global)
        };
        let t = self.rt.meta.train;
        let steps_per_round = (self.cfg.local_epochs * t.nbatches) as f32;
        let param_count = self.rt.meta.param_count;
        let (c_global, c_global_bytes): (Option<Arc<Vec<f32>>>, u64) = match &self.opt {
            ServerOpt::Scaffold(s) => {
                // The server control variate rides the same downlink codec
                // as the model broadcast: transformed once, billed per
                // participant.
                let mut c = s.c.clone();
                let bytes = self.downlink.side_transmit(&mut c);
                (Some(Arc::new(c)), bytes)
            }
            _ => (None, 0),
        };

        // ---- fan-out: one pure job per participant ------------------------
        // Everything per-client is materialized *here*, for participants
        // only: the dataset (lazily synthesized for virtual populations,
        // dropped when the job folds) and the parameter snapshot
        // (reconstructed from the shared init + the client's sparse
        // record). Round cost is O(participants), never O(population).
        //
        // Virtual time is analytic: nominal compute seconds come from the
        // runtime's flops estimate (×local epochs, ÷device gflops), scaled
        // per client by the scheduler's deterministic speed multiplier;
        // transfer seconds come from billed bytes over the asymmetric
        // link. No wall clock is ever consulted, so simulated time is
        // thread-count invariant by construction. Upload bytes are the
        // codec's billed size for the wire lengths this round will send —
        // a pure function of length, so it is known before any job runs.
        let analytic_up_bytes: u64 = if local_only {
            0
        } else {
            self.up_codec.billed_bytes(self.layout.global_len())
                + c_global
                    .as_ref()
                    .map(|c| self.up_codec.billed_bytes(c.len()))
                    .unwrap_or(0)
        };
        let comp_secs = self.rt.train_flops_estimate().unwrap_or(1e7)
            * self.cfg.local_epochs as f64
            / (self.cfg.sched.time.device_gflops * 1e9);
        let mut jobs: Vec<LocalTrainJob> = Vec::with_capacity(participants.len());
        let mut arrivals: Vec<(usize, f64)> = Vec::with_capacity(participants.len());
        let mut fault_losses = 0usize;
        for &cid in &participants {
            // Heterogeneous fleet: the client's device class decides its
            // truncation mask (billed wire length) and compute slowdown.
            // `fleet` is `None` on the homogeneous default — every branch
            // below then takes the historical path bit-for-bit.
            let (rank_mask, active_len, slowdown) = match &self.fleet {
                Some(f) => {
                    let m = f.mask_for(cid);
                    (
                        m.map(|(mask, _)| Arc::clone(mask)),
                        m.map(|&(_, len)| len),
                        f.slowdown(cid),
                    )
                }
                None => (None, None, 1.0),
            };
            // A truncated client uploads (and on a cache miss, downloads)
            // only the coordinates inside its rank budget.
            let client_up_bytes = match active_len {
                Some(len) if !local_only => self.up_codec.billed_bytes(len),
                _ => analytic_up_bytes,
            };
            let mut comm = CommDelta::default();
            let mut down_billed = 0u64;
            if !local_only {
                // Fingerprint-cached redelivery: a client whose last
                // received wire global is bit-identical to this round's
                // broadcast is billed only the hash check. Billing only —
                // the job still carries the broadcast, so training bits
                // are invariant under fingerprinting.
                let cached = wire_hash.is_some()
                    && self.store.last_global_hash(cid) == wire_hash;
                let model_down = if cached {
                    FINGERPRINT_BYTES
                } else {
                    match active_len {
                        Some(len) => self.downlink.side_bytes(len),
                        None => down_model_bytes,
                    }
                };
                comm.record_download(model_down);
                down_billed += model_down;
                if matches!(self.cfg.optimizer, Optimizer::Scaffold) {
                    // Server control variate rides along with the model.
                    comm.record_download(c_global_bytes);
                    down_billed += c_global_bytes;
                }
            }
            match self.sched.fate(self.round, cid) {
                Fate::Healthy => {}
                Fate::Dropout => {
                    // The broadcast went out before the device vanished:
                    // the download is billed, nothing trains, no upload.
                    self.comm.apply(comm);
                    self.sched.note_failure(cid);
                    fault_losses += 1;
                    continue;
                }
                Fate::CrashUpload { frac } => {
                    // Device trained, started uploading, died partway:
                    // bill the download plus the partial upload; the
                    // update never reaches the aggregator.
                    comm.record_upload((client_up_bytes as f64 * frac) as u64);
                    self.comm.apply(comm);
                    self.sched.note_failure(cid);
                    fault_losses += 1;
                    continue;
                }
            }
            arrivals.push((
                cid,
                self.sched.arrival_secs(cid, down_billed, client_up_bytes, comp_secs * slowdown),
            ));
            let opt = match &self.cfg.optimizer {
                Optimizer::FedAvg | Optimizer::FedAdam => JobOpt::Plain,
                Optimizer::FedProx { mu } => JobOpt::Prox { mu: *mu },
                Optimizer::Scaffold => {
                    let c_global = Arc::clone(c_global.as_ref().expect("scaffold state"));
                    let c_i = self.store.control(cid, c_global.len());
                    JobOpt::Scaffold { c_global, c_i, inv_k_eta: 1.0 / (steps_per_round * lr) }
                }
                Optimizer::FedDyn { alpha } => {
                    let lambda = self.store.lambda(cid, param_count);
                    JobOpt::FedDyn { alpha: *alpha, lambda }
                }
            };
            jobs.push(LocalTrainJob {
                cid,
                rt: Arc::clone(&self.rt),
                layout: Arc::clone(&self.layout),
                data: self.store.round_data(cid),
                params: self.store.round_params(cid),
                download: (!local_only).then(|| Arc::clone(&wire_global)),
                // 32-bit split keeps (round, cid) tags collision-free well
                // past the million-client scale the roadmap targets.
                rng: self.root_rng.child((self.round as u64) << 32 | cid as u64),
                lr,
                local_epochs: self.cfg.local_epochs,
                opt,
                up: Arc::clone(&self.up_codec),
                feedback: self
                    .up_codec
                    .uses_feedback()
                    .then(|| self.store.feedback(cid)),
                rank_mask,
                billed_up_len: active_len,
                local_only,
                comm,
                // Reuse last round's scratch where available; the pool
                // grows to the steady-state participant count and then
                // stops allocating.
                scratch: self
                    .scratch_pool
                    .pop()
                    .unwrap_or_else(|| JobScratch::new(&self.rt, self.gemm_backend)),
            });
        }

        // ---- plan the round on the virtual clock --------------------------
        // Admission is a pure function of the analytic arrival times, so
        // the whole plan (who is admitted, deferred, or cut) exists before
        // any job executes — the fold below stays one O(dim) streaming
        // pass. Under the default sync/faultless config the plan admits
        // everyone and the round is bit-identical to the pre-scheduler
        // path.
        let plan = self.sched.plan(&arrivals);
        for r in &plan.ready {
            self.store.set_in_flight(r.cid, false);
        }
        for &cid in &plan.dropped_cids {
            self.store.set_in_flight(cid, false);
        }
        let version_now = self.sched.version();

        // ---- run on the pool, reduce in participant order -----------------
        let needs_full = matches!(
            self.cfg.optimizer,
            Optimizer::Scaffold | Optimizer::FedDyn { .. }
        ) && !local_only;
        // Each accumulator is allocated only for the path that feeds it.
        let upload_dim = if needs_full || local_only { 0 } else { self.layout.global_len() };
        let mut acc_upload = WeightedAccumulator::new(upload_dim);
        // SCAFFOLD folds model/control deltas; FedDyn folds full models.
        let mut acc_a = WeightedAccumulator::new(if needs_full { param_count } else { 0 });
        let mut acc_b = WeightedAccumulator::new(if needs_full { param_count } else { 0 });
        // Carried async uploads admitted this round fold first, in their
        // deterministic (arrival, seq) order, weights already discounted
        // by staleness. Their training loss was counted the round they
        // trained. (Async is restricted to mean-style optimizers, so the
        // plain accumulator is always the right sink.)
        let mut admitted = plan.ready.len();
        for r in &plan.ready {
            // Carried uploads keep their origin client's rank budget: the
            // class is a pure function of (seed, cid), so re-deriving the
            // mask here matches what the client trained with.
            match self.fleet.as_ref().and_then(|f| f.mask_for(r.cid)) {
                Some((mask, _)) => acc_upload.push_masked(&r.upload, r.weight, mask),
                None => acc_upload.push(&r.upload, r.weight),
            }
        }
        let mut loss_acc = 0.0f64;
        let mut first_err: Option<anyhow::Error> = None;
        let t_comp_start = Instant::now();
        {
            let store = &mut self.store;
            let comm = &mut self.comm;
            let server_params = &self.server_params;
            let optimizer = self.cfg.optimizer;
            let scratch_pool = &mut self.scratch_pool;
            let sched = &mut self.sched;
            let decisions = &plan.decisions;
            self.pool.scope_fold_cancel(
                jobs,
                LocalTrainJob::run,
                |idx, outcome: Result<LocalTrainOutcome>| {
                    // A failure flips the pool's cancel flag: queued jobs
                    // are skipped, in-flight jobs drain with their results
                    // discarded, and the committed state is a clean
                    // participant-order prefix — the same shape a
                    // sequential loop leaves on early return.
                    let out = match outcome {
                        Ok(o) => o,
                        Err(e) => {
                            first_err = Some(e);
                            return false;
                        }
                    };
                    scratch_pool.push(out.scratch);
                    comm.apply(out.comm);
                    loss_acc += out.loss_sum;
                    // Persist the client's sparse record (policy decides
                    // how much of `params` survives); the job's dataset
                    // Arc dropped with the job — for virtual populations
                    // nothing data-shaped outlives the fold.
                    store.commit(
                        out.cid,
                        out.params,
                        out.new_control,
                        out.new_lambda,
                        out.feedback,
                        wire_hash,
                    );
                    match decisions[idx] {
                        Decision::Admit => {}
                        Decision::Straggle => {
                            // Finished after the deadline: the client did
                            // train (state committed above) but the upload
                            // is discarded; the fault model may retry it.
                            sched.note_failure(out.cid);
                            return true;
                        }
                        Decision::Defer => {
                            // Async, beyond the first K arrivals: the
                            // upload waits in the server buffer for a
                            // later round's fold, discounted by staleness
                            // when it finally lands.
                            store.set_in_flight(out.cid, true);
                            store.set_last_version(out.cid, version_now);
                            sched.buffer_upload(out.cid, out.upload, out.weight);
                            return true;
                        }
                    }
                    if local_only {
                        return true;
                    }
                    admitted += 1;
                    match optimizer {
                        Optimizer::Scaffold => {
                            // Stream Δθ = (wire model) − θ and Δc, reusing
                            // the upload buffer for the subtraction.
                            let mut delta = out.upload;
                            aggregate::sub_from(&mut delta, server_params);
                            acc_a.push(&delta, 1.0);
                            acc_b.push(&out.delta_control.expect("scaffold delta"), 1.0);
                        }
                        Optimizer::FedDyn { .. } => {
                            acc_a.push(&out.upload, 1.0);
                        }
                        _ => match &out.rank_mask {
                            // Renormalized factor-space aggregation: a
                            // truncated client only votes on coordinates
                            // inside its budget, and each coordinate is
                            // averaged over the weight that actually
                            // covered it (FedHM-style), so leading columns
                            // seen by everyone aren't diluted by zeros.
                            Some(mask) => acc_upload.push_masked(&out.upload, out.weight, mask),
                            None => acc_upload.push(&out.upload, out.weight),
                        },
                    }
                    // The upload drops here — aggregation stays O(dim).
                    true
                },
            );
        }
        let t_comp = t_comp_start.elapsed().as_secs_f64();
        if let Some(e) = first_err {
            return Err(e);
        }

        // ---- aggregation --------------------------------------------------
        // With faults or a deadline in play a round can end with nothing
        // admitted; the server then holds its model (and version) and the
        // round degrades to a no-op instead of dividing by zero.
        let aggregated = !local_only && admitted > 0;
        if aggregated {
            let new_global = match &mut self.opt {
                ServerOpt::Plain => acc_upload.mean_or(&server_global),
                ServerOpt::Adam(adam) => adam.step(&server_global, &acc_upload.mean_or(&server_global)),
                ServerOpt::Scaffold(sc) => {
                    let new_full = sc.step_from_means(
                        &self.server_params,
                        &acc_a.mean(),
                        &acc_b.mean(),
                        admitted,
                    );
                    self.server_params = new_full;
                    self.layout.gather_global(&self.server_params)
                }
                ServerOpt::FedDyn(fd) => {
                    let new_full = fd.step_from_mean(
                        &self.server_params,
                        acc_a.mean(),
                        admitted,
                    );
                    self.server_params = new_full;
                    self.layout.gather_global(&self.server_params)
                }
            };
            self.layout.scatter_global(&mut self.server_params, &new_global);
        }
        self.comm.end_round();
        self.sched.end_round(aggregated, plan.round_secs);

        // ---- report -------------------------------------------------------
        let evaluate = self.cfg.eval_every > 0 && (self.round + 1) % self.cfg.eval_every == 0;
        let (test_acc, test_loss) = if evaluate && !local_only {
            let e = self.evaluate_global()?;
            (Some(e.accuracy()), Some(e.mean_loss()))
        } else {
            (None, None)
        };
        let (up, down) = *self.comm.per_round.last().unwrap();
        let report = RoundReport {
            round: self.round,
            lr,
            participants: participants.len(),
            mean_train_loss: loss_acc
                / (participants.len().max(1) * self.cfg.local_epochs) as f64,
            up_bytes: up,
            down_bytes: down,
            cum_gbytes: self.comm.total_gbytes(),
            cum_energy_mj: self.comm.total_energy_mj(),
            test_acc,
            test_loss,
            t_comp_secs: t_comp,
            t_sim_secs: plan.round_secs,
            stragglers: plan.stragglers,
            dropped: fault_losses + plan.dropped_cids.len(),
        };
        self.round += 1;
        self.reports.push(report.clone());
        Ok(report)
    }

    /// Run `rounds` rounds, returning the reports.
    pub fn run(&mut self, rounds: usize) -> Result<&[RoundReport]> {
        for _ in 0..rounds {
            let r = self.run_round()?;
            if crate::util::logging::enabled(crate::util::logging::Level::Info) {
                let acc = r.test_acc.map(|a| format!("{:.2}%", a * 100.0)).unwrap_or_default();
                crate::log_info!(
                    "round {:>4}  loss {:.4}  lr {:.4}  cum {:.4} GB  {}",
                    r.round,
                    r.mean_train_loss,
                    r.lr,
                    r.cum_gbytes,
                    acc
                );
            }
        }
        Ok(&self.reports)
    }

    /// Evaluate the current global model on the shared test set. Runs on
    /// the coordinator thread while the fan-out pool is idle; the cached
    /// workspace (pool attached) makes repeated per-round evaluation
    /// allocation-free and row-parallel.
    pub fn evaluate_global(&self) -> Result<EvalOutput> {
        let mut ws = self.eval_scratch.lock().expect("eval workspace lock poisoned");
        eval_on_ws(&self.rt, &mut ws, &self.server_params, &self.test)
    }

    /// Evaluate each client's *personalized* model (its full parameter
    /// vector, local segments included) on its own test set — the Figure-5
    /// protocol. Returns per-client accuracies.
    pub fn evaluate_personalized(&self, client_tests: &[Dataset]) -> Result<Vec<f64>> {
        if client_tests.len() != self.store.population() {
            return Err(anyhow!("need one test set per client"));
        }
        // The download is client-invariant: gather the server's global view
        // once, not once per client. The cached eval workspace serves the
        // whole sweep.
        let global = (!matches!(self.cfg.sharing, Sharing::LocalOnly))
            .then(|| self.layout.gather_global(&self.server_params));
        let mut ws = self.eval_scratch.lock().expect("eval workspace lock poisoned");
        let mut accs = Vec::with_capacity(client_tests.len());
        for (cid, t) in client_tests.iter().enumerate() {
            // A client that never trained evaluates its (implicit) init —
            // fine; the store reconstructs a touched client's persisted
            // segments.
            let mut params = self.store.round_params(cid);
            if let Some(g) = &global {
                // Personalized model = latest global + own local segments.
                self.layout.scatter_global(&mut params, g);
            }
            accs.push(eval_on_ws(&self.rt, &mut ws, &params, t)?.accuracy());
        }
        Ok(accs)
    }

    /// Snapshot of the server model (global vector view).
    pub fn server_global(&self) -> Vec<f32> {
        self.layout.gather_global(&self.server_params)
    }
}

/// Evaluate `params` on a whole dataset by chunking it through the fixed
/// eval shape. The final chunk is padded by wrapping around to the front of
/// the dataset, but only the `valid` fresh samples are counted
/// (`eval_call_partial` masks the pad), so the merged output covers every
/// sample exactly once for **any** test-set size.
pub fn eval_on(rt: &ModelRuntime, params: &[f32], data: &Dataset) -> Result<EvalOutput> {
    eval_on_ws(rt, &mut EvalScratch::new(rt), params, data)
}

/// Pooled evaluation scratch: the runtime [`Workspace`] plus the stacked
/// x/y chunk-staging buffers [`eval_on_ws`] fills per eval call — so a
/// reused scratch keeps whole-dataset (and per-client personalized)
/// evaluation entirely off the allocator.
pub struct EvalScratch {
    ws: Workspace,
    x: Vec<f32>,
    y: Vec<f32>,
}

impl EvalScratch {
    pub fn new(rt: &ModelRuntime) -> EvalScratch {
        EvalScratch { ws: rt.workspace(), x: Vec::new(), y: Vec::new() }
    }

    /// See [`Workspace::set_pool`] (same safety caveat).
    pub fn set_pool(&mut self, pool: Option<Arc<ThreadPool>>) {
        self.ws.set_pool(pool);
    }

    /// See [`Workspace::set_backend`].
    pub fn set_backend(&mut self, backend: GemmBackend) {
        self.ws.set_backend(backend);
    }
}

/// [`eval_on`] with caller-owned scratch: weights compose into the scratch
/// workspace once per call and the stacked x/y chunk buffers are reused
/// across chunks and calls.
pub fn eval_on_ws(
    rt: &ModelRuntime,
    scratch: &mut EvalScratch,
    params: &[f32],
    data: &Dataset,
) -> Result<EvalOutput> {
    if data.is_empty() {
        return Err(anyhow!("empty test set"));
    }
    let e = rt.meta.eval;
    let need = e.samples_per_call();
    let mut merged: Option<EvalOutput> = None;
    let EvalScratch { ws, x, y } = scratch;
    let mut start = 0usize;
    while start < data.len() {
        let valid = (data.len() - start).min(need);
        x.clear();
        x.reserve(need * data.feature_dim);
        y.clear();
        y.reserve(need);
        for i in 0..need {
            let (f, l) = data.sample((start + i) % data.len());
            x.extend_from_slice(f);
            y.push(l as f32);
        }
        let out = rt.eval_call_partial_ws(ws, params, x, y, valid)?;
        match merged.as_mut() {
            Some(m) => m.merge(&out),
            None => merged = Some(out),
        }
        start += need;
    }
    merged.ok_or_else(|| anyhow!("empty test set"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_vision;

    /// The gather-hoist in `evaluate_personalized` must not change any
    /// per-client accuracy: recompute with the pre-hoist formulation (one
    /// gather per client) and require identical results.
    #[test]
    fn personalized_eval_unchanged_by_gather_hoist() {
        let engine = Engine::native();
        let spec = synth_vision::mnist_like();
        let clients = 4usize;
        let locals: Vec<Dataset> =
            (0..clients).map(|i| synth_vision::generate(&spec, 48, 100 + i as u64)).collect();
        let tests: Vec<Dataset> =
            (0..clients).map(|i| synth_vision::generate(&spec, 32, 200 + i as u64)).collect();
        let cfg = RunConfig {
            artifact: "native_mlp10_pfedpara".into(),
            sample_frac: 1.0,
            rounds: 2,
            local_epochs: 1,
            lr: 0.05,
            lr_decay: 1.0,
            optimizer: Optimizer::FedAvg,
            wire: Default::default(),
            sharing: Sharing::GlobalSegments,
            sched: Default::default(),
            devices: Default::default(),
            eval_every: 0,
            seed: 9,
            num_threads: 1,
        };
        let mut fed = Federation::new(&engine, cfg, locals, tests[0].clone()).unwrap();
        fed.run(2).unwrap();
        let hoisted = fed.evaluate_personalized(&tests).unwrap();
        let mut reference = Vec::new();
        for (cid, t) in tests.iter().enumerate() {
            let mut params = fed.store.round_params(cid);
            let g = fed.layout.gather_global(&fed.server_params);
            fed.layout.scatter_global(&mut params, &g);
            reference.push(eval_on(&fed.rt, &params, t).unwrap().accuracy());
        }
        assert_eq!(hoisted, reference);
        assert_eq!(hoisted.len(), clients);
    }
}
