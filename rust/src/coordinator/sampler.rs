//! Client sampling: each round the server samples a fraction of clients
//! uniformly without replacement (FedAvg; the paper samples 16% of 100
//! clients). Deterministic given (seed, round).
//!
//! Cost is O(per_round), not O(population): `Rng::sample_indices` is the
//! sparse partial Fisher-Yates, so sampling 1000 of 10⁶ virtual clients
//! allocates kilobytes, not megabytes — the sampler is safe to sit in the
//! cross-device hot loop.

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Sampler {
    num_clients: usize,
    per_round: usize,
    root: Rng,
}

impl Sampler {
    /// `frac` of `num_clients` per round, at least 1.
    pub fn new(num_clients: usize, frac: f64, seed: u64) -> Sampler {
        assert!(num_clients > 0);
        assert!((0.0..=1.0).contains(&frac));
        let per_round = ((num_clients as f64 * frac).round() as usize).clamp(1, num_clients);
        Sampler { num_clients, per_round, root: Rng::new(seed ^ 0x5A3B_17) }
    }

    /// All clients every round (the paper's Figure-5 personalization setup
    /// assumes no sub-sampling).
    pub fn full(num_clients: usize) -> Sampler {
        Sampler::new(num_clients, 1.0, 0)
    }

    pub fn per_round(&self) -> usize {
        self.per_round
    }

    pub fn population(&self) -> usize {
        self.num_clients
    }

    /// Sample the participant set for `round` (sorted for determinism of
    /// downstream iteration order).
    pub fn sample(&self, round: usize) -> Vec<usize> {
        self.sample_n(round, self.per_round)
    }

    /// Sample `k` participants for `round` — the over-selection hook for
    /// deadline scheduling. `sample_n(round, per_round())` is exactly the
    /// historical `sample` draw (same child stream, same Fisher-Yates
    /// sequence), so the default path stays bit-identical.
    pub fn sample_n(&self, round: usize, k: usize) -> Vec<usize> {
        let k = k.clamp(1, self.num_clients);
        // Full participation sorts to exactly 0..n whatever the draw —
        // skip the n rng draws (the per-round child rng is discarded, so
        // the output is identical).
        if k == self.num_clients {
            return (0..self.num_clients).collect();
        }
        let mut rng = self.root.child(round as u64);
        let mut ids = rng.sample_indices(self.num_clients, k);
        ids.sort_unstable();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respects_fraction() {
        let s = Sampler::new(100, 0.16, 1);
        assert_eq!(s.per_round(), 16);
        assert_eq!(s.sample(0).len(), 16);
    }

    #[test]
    fn at_least_one() {
        let s = Sampler::new(10, 0.01, 1);
        assert_eq!(s.per_round(), 1);
    }

    #[test]
    fn deterministic_per_round() {
        let s1 = Sampler::new(50, 0.2, 7);
        let s2 = Sampler::new(50, 0.2, 7);
        for r in 0..5 {
            assert_eq!(s1.sample(r), s2.sample(r));
        }
        assert_ne!(s1.sample(0), s1.sample(1));
    }

    #[test]
    fn distinct_in_range_sorted() {
        let s = Sampler::new(30, 0.5, 3);
        for r in 0..10 {
            let ids = s.sample(r);
            let mut d = ids.clone();
            d.dedup();
            assert_eq!(d.len(), ids.len(), "duplicates in round {r}");
            assert!(ids.windows(2).all(|w| w[0] < w[1]));
            assert!(ids.iter().all(|&i| i < 30));
        }
    }

    #[test]
    fn covers_all_clients_over_time() {
        let s = Sampler::new(20, 0.25, 5);
        let mut seen = vec![false; 20];
        for r in 0..60 {
            for i in s.sample(r) {
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&x| x), "some client never sampled");
    }

    #[test]
    fn full_sampler() {
        let s = Sampler::full(7);
        assert_eq!(s.sample(3), (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn full_fast_path_matches_generic_draw() {
        // The per_round == num_clients shortcut must equal the sorted
        // full Fisher-Yates draw (a permutation sorts to 0..n).
        let s = Sampler::new(40, 1.0, 9);
        let mut rng = s.root.child(5);
        let mut generic = rng.sample_indices(40, 40);
        generic.sort_unstable();
        assert_eq!(s.sample(5), generic);
    }

    #[test]
    fn sample_n_extends_the_same_draw() {
        // Over-selection shares the per-round stream: k = per_round is the
        // historical draw, larger k is the same Fisher-Yates continued.
        let s = Sampler::new(50, 0.2, 7);
        for r in 0..5 {
            assert_eq!(s.sample_n(r, s.per_round()), s.sample(r));
            let over = s.sample_n(r, 15);
            assert_eq!(over.len(), 15);
            assert!(over.windows(2).all(|w| w[0] < w[1]));
            for id in s.sample(r) {
                assert!(over.contains(&id), "over-selection must contain the base draw");
            }
        }
        // k clamps to the population (full fast path) and to at least 1.
        assert_eq!(s.sample_n(0, 500), (0..50).collect::<Vec<_>>());
        assert_eq!(s.sample_n(0, 0).len(), 1);
    }

    #[test]
    fn population_scale_sampling_is_cheap_and_valid() {
        // 1000 of 1M virtual clients: distinct, in-range, sorted,
        // deterministic — and O(per_round), so this test is instant.
        let s = Sampler::new(1_000_000, 0.001, 42);
        assert_eq!(s.per_round(), 1000);
        for round in [0usize, 1, 999] {
            let ids = s.sample(round);
            assert_eq!(ids.len(), 1000);
            assert!(ids.windows(2).all(|w| w[0] < w[1]), "sorted + distinct");
            assert!(*ids.last().unwrap() < 1_000_000);
            assert_eq!(ids, s.sample(round));
        }
        assert_ne!(s.sample(0), s.sample(1));
    }
}
