//! The L3 federated-learning coordinator (the paper's Algorithms 1–2 plus
//! the optimizer strategies of Table 3 and the transfer policies of §2.3).

pub mod aggregate;
pub mod client;
pub mod comm;
pub mod sampler;
pub mod sched;
pub mod server;
pub mod store;
pub mod wire;

pub use comm::{CommLedger, Network};
pub use sched::{EventQueue, Fate, RoundPlan, Scheduler};
pub use wire::{WireCodec, WirePayload, FINGERPRINT_BYTES};
pub use server::{eval_on, eval_on_ws, EvalScratch, Federation, RoundReport};
pub use store::{ClientDataSource, ClientStore, ParamPolicy, RoundData};
