//! Minimal scoped thread pool (no `tokio`/`rayon` offline).
//!
//! The coordinator uses this to fan client local-training jobs out across
//! cores. On the single-core CI box the pool degenerates to sequential
//! execution, but the structure (and its tests) keep the runtime ready for
//! multi-core hosts. Jobs are `FnOnce` closures; `scope_map` provides the
//! common "map a function over items in parallel, preserving order" shape,
//! and `scope_fold` is its streaming form: results are folded **on the
//! calling thread, in input order, as soon as they (and all earlier
//! results) are available** — the round loop uses it to merge client
//! uploads into the aggregation accumulator while keeping the fold order
//! (and therefore all floating-point results) independent of the pool
//! size. Out-of-order completions buffer until their turn, so the memory
//! win over collect-then-fold is typical-case, not worst-case.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size worker pool. Dropping the pool joins all workers.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    sender: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    /// Create a pool with `size` workers (min 1).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                thread::Builder::new()
                    .name(format!("fedpara-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // Sender dropped: shut down.
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { workers, sender: Some(sender) }
    }

    /// Workers a host-sized pool uses: one per available core. The old cap
    /// of 8 existed for the PJRT backend's per-call single-threading; with
    /// PJRT calls now mutex-serialized and the native backend fully
    /// parallel, the host size is the right default.
    pub fn host_parallelism() -> usize {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    /// Pool sized to the machine (one worker per available core).
    pub fn for_host() -> ThreadPool {
        ThreadPool::new(ThreadPool::host_parallelism())
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a fire-and-forget job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Run borrowed closures on the pool, blocking until every one has
    /// completed. This is the scoped counterpart of [`execute`]: tasks may
    /// capture non-`'static` references (slices of a caller-owned buffer,
    /// typically disjoint `chunks_mut` of one output), which the kernel
    /// row-parallelism in `linalg::kernels` uses to split a GEMM without
    /// copying its operands.
    ///
    /// Panics in tasks are re-raised here after **all** tasks have
    /// finished, so no task can outlive the borrows it captured.
    ///
    /// Must not be called from inside a job running on this same pool:
    /// with every worker occupied by blocked callers the inner tasks would
    /// never be scheduled.
    ///
    /// [`execute`]: ThreadPool::execute
    pub fn run_borrowed<'a>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        let (tx, rx) = mpsc::channel::<thread::Result<()>>();
        for task in tasks {
            // SAFETY: the loop below blocks until every task has sent its
            // completion (or panic) before this function returns, so the
            // `'a` borrows captured by the task strictly outlive its
            // execution; extending the closure's lifetime to `'static` for
            // the queue hand-off is therefore sound. Workers never drop a
            // received job without running it, and the channel send cannot
            // fail while `rx` is held here.
            let task: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(task) };
            let tx = tx.clone();
            self.execute(move || {
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(task));
                let _ = tx.send(out);
            });
        }
        drop(tx);
        let mut panicked = None;
        for _ in 0..n {
            match rx.recv().expect("worker dropped a borrowed task") {
                Ok(()) => {}
                Err(p) => panicked = Some(p),
            }
        }
        if let Some(p) = panicked {
            std::panic::resume_unwind(p);
        }
    }

    /// Map `f` over `items` on the pool, blocking until all complete, and
    /// return outputs in input order. Panics in jobs are propagated.
    pub fn scope_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let mut out = Vec::with_capacity(items.len());
        self.scope_fold(items, f, |_, r| out.push(r));
        out
    }

    /// Map `f` over `items` on the pool and fold each result with
    /// `fold(index, result)` **on the calling thread, in input order**, as
    /// soon as the result (and all earlier ones) are available. Results
    /// that finish out of order are buffered until their turn, so the fold
    /// sequence — and any floating-point accumulation inside it — is
    /// bit-identical for every pool size. Panics in jobs are propagated.
    pub fn scope_fold<T, R, F, G>(&self, items: Vec<T>, f: F, mut fold: G)
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
        G: FnMut(usize, R),
    {
        self.scope_fold_cancel(items, f, move |i, r| {
            fold(i, r);
            true
        });
    }

    /// [`scope_fold`] with cooperative cancellation: `fold` returns `false`
    /// to cancel the remaining work. A shared flag is checked before each
    /// queued job starts, so jobs that have not begun are skipped (no
    /// wasted CPU on a doomed round); jobs already in flight still drain —
    /// their results are received but no longer folded. Every item is
    /// accounted for either way, so the call always returns only after the
    /// pool holds no reference to this scope. Panics in jobs are
    /// propagated.
    ///
    /// [`scope_fold`]: ThreadPool::scope_fold
    pub fn scope_fold_cancel<T, R, F, G>(&self, items: Vec<T>, f: F, mut fold: G)
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
        G: FnMut(usize, R) -> bool,
    {
        let n = items.len();
        if n == 0 {
            return;
        }
        let f = Arc::new(f);
        let cancel = Arc::new(AtomicBool::new(false));
        // `None` marks a job skipped by cancellation — it still occupies
        // its slot in the ordered drain so `next` advances past it.
        let (tx, rx) = mpsc::channel::<(usize, Option<thread::Result<R>>)>();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            let cancel = Arc::clone(&cancel);
            self.execute(move || {
                if cancel.load(Ordering::SeqCst) {
                    let _ = tx.send((i, None));
                    return;
                }
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(item)));
                // Receiver may be gone if an earlier job already panicked.
                let _ = tx.send((i, Some(out)));
            });
        }
        drop(tx);
        let mut pending: BTreeMap<usize, Option<R>> = BTreeMap::new();
        let mut next = 0usize;
        let mut live = true;
        for _ in 0..n {
            let (i, res) = rx.recv().expect("all senders dropped early");
            match res {
                None => {
                    pending.insert(i, None);
                }
                Some(Ok(r)) => {
                    pending.insert(i, Some(r));
                }
                Some(Err(p)) => std::panic::resume_unwind(p),
            }
            while let Some(slot) = pending.remove(&next) {
                if let Some(r) = slot {
                    if live && !fold(next, r) {
                        live = false;
                        cancel.store(true, Ordering::SeqCst);
                    }
                }
                next += 1;
            }
        }
        debug_assert_eq!(next, n, "scope_fold missed results");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Close the channel so workers exit, then join them.
        self.sender.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                tx.send(()).unwrap();
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scope_map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.scope_map((0..50).collect::<Vec<usize>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn scope_map_on_single_worker() {
        let pool = ThreadPool::new(1);
        let out = pool.scope_map(vec![3usize, 1, 4], |x| x + 1);
        assert_eq!(out, vec![4, 2, 5]);
    }

    #[test]
    fn scope_map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.scope_map(Vec::<usize>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn scope_map_propagates_panics() {
        let pool = ThreadPool::new(2);
        pool.scope_map(vec![0usize, 1, 2], |x| {
            if x == 1 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn scope_fold_applies_in_input_order() {
        // Jobs finish in scrambled order (later items sleep less), yet the
        // fold must still observe indices 0, 1, 2, ... strictly in order.
        let pool = ThreadPool::new(4);
        let items: Vec<usize> = (0..32).collect();
        let mut seen = Vec::new();
        pool.scope_fold(
            items,
            |i| {
                std::thread::sleep(std::time::Duration::from_millis(((32 - i) % 7) as u64));
                i * 10
            },
            |idx, r| {
                assert_eq!(r, idx * 10);
                seen.push(idx);
            },
        );
        assert_eq!(seen, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn scope_fold_more_jobs_than_workers() {
        // Oversubscription stress: far more jobs than workers, with enough
        // work per job that the queue actually backs up.
        let pool = ThreadPool::new(2);
        let items: Vec<u64> = (0..200).collect();
        let mut sum = 0u64;
        pool.scope_fold(
            items,
            |x| {
                // A little busy-work so jobs overlap in flight.
                let mut acc = 0u64;
                for k in 0..1000 {
                    acc = acc.wrapping_add(x * k);
                }
                std::hint::black_box(acc);
                x * x
            },
            |_, r| sum += r,
        );
        assert_eq!(sum, (0..200u64).map(|x| x * x).sum::<u64>());
    }

    #[test]
    fn scope_fold_empty() {
        let pool = ThreadPool::new(2);
        let mut calls = 0;
        pool.scope_fold(Vec::<usize>::new(), |x| x, |_, _| calls += 1);
        assert_eq!(calls, 0);
    }

    #[test]
    fn scope_fold_cancel_skips_queued_jobs_after_error() {
        // One worker, job 0 fails immediately, jobs 1..N block on a gate
        // released by the cancelling fold. The fold cancels on the first
        // (failed) result, so at most the one job already dequeued by the
        // worker can still run — every other queued job must be skipped
        // before `f` starts, while the scope still drains all N+1 slots.
        const N: usize = 64;
        let pool = ThreadPool::new(1);
        let executed = Arc::new(AtomicUsize::new(0));
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let gate_rx = Arc::new(Mutex::new(gate_rx));
        let exec = Arc::clone(&executed);
        let mut fold_calls = 0usize;
        pool.scope_fold_cancel(
            (0..=N).collect::<Vec<usize>>(),
            move |i| {
                if i == 0 {
                    return Err("boom");
                }
                // In-flight jobs drain: they wait for the gate, then run.
                gate_rx.lock().unwrap().recv().unwrap();
                exec.fetch_add(1, Ordering::SeqCst);
                Ok(i)
            },
            |idx, r: Result<usize, &str>| {
                fold_calls += 1;
                assert_eq!(idx, 0, "fold must stop being called after cancelling");
                assert!(r.is_err());
                // Release every gated job *before* cancelling, so any job
                // already past the flag check can finish (drain), while
                // the rest observe the flag and skip.
                for _ in 0..N {
                    gate_tx.send(()).unwrap();
                }
                false
            },
        );
        assert_eq!(fold_calls, 1, "results after a cancel are not folded");
        let ran = executed.load(Ordering::SeqCst);
        assert!(ran <= 2, "only jobs in flight at cancel time may run, {ran} did");
    }

    #[test]
    fn scope_fold_cancel_suppresses_fold_after_false() {
        // Multi-worker: cancel at index 10 of 200. All 200 slots drain
        // (the call returns), but the fold sees exactly indices 0..=10.
        let pool = ThreadPool::new(4);
        let mut seen = Vec::new();
        pool.scope_fold_cancel(
            (0..200usize).collect::<Vec<_>>(),
            |x| x,
            |idx, r| {
                assert_eq!(idx, r);
                seen.push(idx);
                idx < 10
            },
        );
        assert_eq!(seen, (0..=10).collect::<Vec<_>>());
    }

    #[test]
    fn scope_fold_cancel_without_cancel_matches_scope_fold() {
        let pool = ThreadPool::new(3);
        let mut a = Vec::new();
        pool.scope_fold_cancel((0..40usize).collect::<Vec<_>>(), |x| x * 3, |_, r| {
            a.push(r);
            true
        });
        assert_eq!(a, (0..40).map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn run_borrowed_fills_disjoint_chunks() {
        // Tasks borrow disjoint chunks of a stack-local buffer — the shape
        // the row-blocked GEMM uses. All writes must land before return.
        let pool = ThreadPool::new(4);
        let mut out = vec![0u64; 64];
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
            .chunks_mut(7)
            .enumerate()
            .map(|(ci, chunk)| {
                let f: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = (ci * 7 + j) as u64 + 1;
                    }
                });
                f
            })
            .collect();
        pool.run_borrowed(tasks);
        assert_eq!(out, (1..=64u64).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "borrowed boom")]
    fn run_borrowed_propagates_panics_after_completion() {
        let pool = ThreadPool::new(2);
        let done = Arc::new(AtomicUsize::new(0));
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
            .map(|i| {
                let done = Arc::clone(&done);
                let f: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    if i == 3 {
                        panic!("borrowed boom");
                    }
                    done.fetch_add(1, Ordering::SeqCst);
                });
                f
            })
            .collect();
        pool.run_borrowed(tasks); // Panics, but only after all 8 ran.
    }

    #[test]
    fn run_borrowed_empty_is_noop() {
        let pool = ThreadPool::new(2);
        pool.run_borrowed(Vec::new());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        let flag = Arc::new(AtomicUsize::new(0));
        let f = Arc::clone(&flag);
        pool.execute(move || {
            f.fetch_add(1, Ordering::SeqCst);
        });
        drop(pool); // Must not hang; job must have run.
        assert_eq!(flag.load(Ordering::SeqCst), 1);
    }
}
