//! Minimal JSON parser/emitter.
//!
//! The offline build has no `serde`; the coordinator only needs JSON for
//! `artifacts/manifest.json` (written by `python/compile/aot.py`) and for
//! the `results/*.json` experiment outputs, so a small, strict RFC 8259
//! subset implementation is enough: objects, arrays, strings (with the
//! standard escapes), f64 numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are kept in a BTreeMap so emission is
/// deterministic (stable diffs for results files).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]`-style access; returns Null for missing keys or non-objects.
    ///
    /// Convenient for results files where absent and null coincide, but it
    /// cannot distinguish a *missing* key from an *explicit* `null` — strict
    /// loaders (the scenario-manifest parser) should go through [`JsonPath`]
    /// instead, which keeps that distinction and reports full key paths.
    pub fn get(&self, key: &str) -> &Json {
        const NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Like [`Json::get`] but preserves the missing-vs-null distinction:
    /// `None` only when the key is absent (or `self` is not an object).
    pub fn get_opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    // -- constructors -----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // -- emission ---------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty-print with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; emit null like most tolerant emitters.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{}", n));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Human-readable type name for error messages.
fn type_name(j: &Json) -> &'static str {
    match j {
        Json::Null => "null",
        Json::Bool(_) => "a boolean",
        Json::Num(_) => "a number",
        Json::Str(_) => "a string",
        Json::Arr(_) => "an array",
        Json::Obj(_) => "an object",
    }
}

/// Path-aware accessor over a parsed [`Json`] tree for strict loaders.
///
/// Every error carries the dotted path of the offending node (e.g.
/// `` `optimizer.mu`: expected a number, got null ``), and — unlike
/// [`Json::get`] — a *missing* key is distinguishable from an *explicit*
/// `null`: [`JsonPath::key`] fails on absence, [`JsonPath::key_opt`] returns
/// `Some` for a present-but-null value so the typed getter can then report
/// the null with its path.
#[derive(Clone)]
pub struct JsonPath<'a> {
    json: &'a Json,
    path: String,
}

impl<'a> JsonPath<'a> {
    pub fn root(json: &'a Json) -> JsonPath<'a> {
        JsonPath { json, path: String::new() }
    }

    pub fn json(&self) -> &'a Json {
        self.json
    }

    /// The dotted path of this node (`(root)` at the top level).
    pub fn path(&self) -> &str {
        if self.path.is_empty() {
            "(root)"
        } else {
            &self.path
        }
    }

    fn child_path(&self, key: &str) -> String {
        if self.path.is_empty() {
            key.to_string()
        } else {
            format!("{}.{key}", self.path)
        }
    }

    /// Descend into a required object key; errors name the missing path.
    pub fn key(&self, key: &str) -> Result<JsonPath<'a>, String> {
        match self.json {
            Json::Obj(o) => match o.get(key) {
                Some(v) => Ok(JsonPath { json: v, path: self.child_path(key) }),
                None => Err(format!("missing required key `{}`", self.child_path(key))),
            },
            other => Err(format!(
                "`{}`: expected an object, got {}",
                self.path(),
                type_name(other)
            )),
        }
    }

    /// Descend into an optional key: `Ok(None)` when absent, `Ok(Some(..))`
    /// when present — including an explicit `null`, which a subsequent typed
    /// getter rejects with the full path.
    pub fn key_opt(&self, key: &str) -> Result<Option<JsonPath<'a>>, String> {
        match self.json {
            Json::Obj(o) => Ok(o
                .get(key)
                .map(|v| JsonPath { json: v, path: self.child_path(key) })),
            other => Err(format!(
                "`{}`: expected an object, got {}",
                self.path(),
                type_name(other)
            )),
        }
    }

    /// Index into an array element; the path gains an `[i]` segment.
    pub fn index(&self, i: usize) -> Result<JsonPath<'a>, String> {
        match self.json {
            Json::Arr(items) => match items.get(i) {
                Some(v) => Ok(JsonPath { json: v, path: format!("{}[{i}]", self.path) }),
                None => Err(format!(
                    "`{}`: index {i} out of bounds (len {})",
                    self.path(),
                    items.len()
                )),
            },
            other => Err(format!(
                "`{}`: expected an array, got {}",
                self.path(),
                type_name(other)
            )),
        }
    }

    fn type_err(&self, want: &str) -> String {
        format!("`{}`: expected {}, got {}", self.path(), want, type_name(self.json))
    }

    pub fn str(&self) -> Result<&'a str, String> {
        self.json.as_str().ok_or_else(|| self.type_err("a string"))
    }

    pub fn f64(&self) -> Result<f64, String> {
        self.json.as_f64().ok_or_else(|| self.type_err("a number"))
    }

    pub fn usize(&self) -> Result<usize, String> {
        self.json
            .as_usize()
            .ok_or_else(|| self.type_err("a non-negative integer"))
    }

    /// JSON numbers are f64, so integers are exact only up to 2^53.
    pub fn u64(&self) -> Result<u64, String> {
        match self.json {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 9_007_199_254_740_992.0 => {
                Ok(*n as u64)
            }
            _ => Err(self.type_err("a non-negative integer (< 2^53)")),
        }
    }

    pub fn bool(&self) -> Result<bool, String> {
        self.json.as_bool().ok_or_else(|| self.type_err("a boolean"))
    }

    pub fn arr(&self) -> Result<Vec<JsonPath<'a>>, String> {
        match self.json {
            Json::Arr(items) => Ok(items
                .iter()
                .enumerate()
                .map(|(i, v)| JsonPath { json: v, path: format!("{}[{i}]", self.path) })
                .collect()),
            _ => Err(self.type_err("an array")),
        }
    }

    /// Reject keys outside `allowed` — typo detection for strict schemas.
    pub fn expect_keys(&self, allowed: &[&str]) -> Result<(), String> {
        match self.json {
            Json::Obj(o) => {
                for k in o.keys() {
                    if !allowed.contains(&k.as_str()) {
                        return Err(format!(
                            "unknown key `{}` (allowed: {})",
                            self.child_path(k),
                            allowed.join(", ")
                        ));
                    }
                }
                Ok(())
            }
            other => Err(format!(
                "`{}`: expected an object, got {}",
                self.path(),
                type_name(other)
            )),
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Surrogate pairs.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Re-decode UTF-8 starting at pos-1.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.peek().ok_or_else(|| self.err("bad \\u escape"))?;
            self.pos += 1;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-12").unwrap(), Json::Num(-12.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("c"));
        assert_eq!(j.get("d"), &Json::Null);
        assert_eq!(j.get("missing"), &Json::Null);
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\nb\t\"q\" A 😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"q\" A 😀");
    }

    #[test]
    fn parse_unicode_passthrough() {
        let j = Json::parse("\"héllo — ünïcode\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo — ünïcode");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"fedpara","params":[1,2.5,-3],"nested":{"ok":true,"x":null},"s":"a\"b"}"#;
        let j = Json::parse(src).unwrap();
        let emitted = j.to_string();
        let j2 = Json::parse(&emitted).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn pretty_roundtrip() {
        let j = Json::obj(vec![
            ("a", Json::arr_f64(&[1.0, 2.0])),
            ("b", Json::Str("x".into())),
        ]);
        let pretty = j.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn integers_emitted_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(42.5).to_string(), "42.5");
    }

    #[test]
    fn usize_accessor() {
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
        assert_eq!(Json::parse("7.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-7").unwrap().as_usize(), None);
    }

    #[test]
    fn get_opt_distinguishes_missing_from_null() {
        let j = Json::parse(r#"{"a": null, "b": 1}"#).unwrap();
        assert_eq!(j.get_opt("a"), Some(&Json::Null));
        assert_eq!(j.get_opt("missing"), None);
        // `get` conflates the two — that is exactly what JsonPath fixes.
        assert_eq!(j.get("a"), j.get("missing"));
    }

    #[test]
    fn jsonpath_reports_full_key_path() {
        let j = Json::parse(r#"{"optimizer": {"kind": "fedprox", "mu": null}}"#).unwrap();
        let root = JsonPath::root(&j);
        let opt = root.key("optimizer").unwrap();
        // Explicit null is *present* (key_opt → Some) but fails typed access
        // with the dotted path in the message.
        let mu = opt.key_opt("mu").unwrap().expect("null is present");
        let err = mu.f64().unwrap_err();
        assert_eq!(err, "`optimizer.mu`: expected a number, got null");
        // Missing key names the would-be path.
        let err = opt.key("alpha").unwrap_err();
        assert_eq!(err, "missing required key `optimizer.alpha`");
        assert_eq!(opt.key_opt("alpha").unwrap().map(|p| p.path().to_string()), None);
    }

    #[test]
    fn jsonpath_typed_getters() {
        let j = Json::parse(r#"{"s":"x","n":2.5,"i":7,"b":true,"a":[1,"two"]}"#).unwrap();
        let root = JsonPath::root(&j);
        assert_eq!(root.key("s").unwrap().str().unwrap(), "x");
        assert_eq!(root.key("n").unwrap().f64().unwrap(), 2.5);
        assert_eq!(root.key("i").unwrap().usize().unwrap(), 7);
        assert_eq!(root.key("i").unwrap().u64().unwrap(), 7);
        assert!(root.key("b").unwrap().bool().unwrap());
        assert!(root.key("n").unwrap().usize().is_err());
        let items = root.key("a").unwrap().arr().unwrap();
        assert_eq!(items.len(), 2);
        assert_eq!(items[1].path(), "a[1]");
        let err = items[1].f64().unwrap_err();
        assert_eq!(err, "`a[1]`: expected a number, got a string");
        assert_eq!(root.key("a").unwrap().index(5).unwrap_err(),
            "`a`: index 5 out of bounds (len 2)");
    }

    #[test]
    fn jsonpath_unknown_key_detection() {
        let j = Json::parse(r#"{"dataset": {"source": "mnist", "foo": 1}}"#).unwrap();
        let root = JsonPath::root(&j);
        assert!(root.expect_keys(&["dataset"]).is_ok());
        let ds = root.key("dataset").unwrap();
        let err = ds.expect_keys(&["source", "clients"]).unwrap_err();
        assert!(err.starts_with("unknown key `dataset.foo`"), "{err}");
    }

    #[test]
    fn jsonpath_non_object_descent() {
        let j = Json::parse("[1,2]").unwrap();
        let root = JsonPath::root(&j);
        assert_eq!(root.key("x").unwrap_err(), "`(root)`: expected an object, got an array");
    }
}
