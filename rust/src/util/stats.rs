//! Small statistics helpers: mean/std, 95% confidence intervals (used by
//! Figure 5 / Table 4 style repeated-run experiments), quantiles, and a
//! streaming Welford accumulator for the bench harness.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator); 0.0 for n < 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Two-sided 95% CI half-width using Student's t critical values.
/// The paper reports 95% CIs over 5 (Fig 5) and 8 (Table 4) repeats.
pub fn ci95_half_width(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    t_crit_95(n - 1) * std_dev(xs) / (n as f64).sqrt()
}

/// t distribution 97.5th percentile by degrees of freedom (table lookup,
/// asymptotes to the normal 1.96).
fn t_crit_95(dof: usize) -> f64 {
    const TABLE: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, 2.201, 2.179,
        2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, 2.080, 2.074, 2.069, 2.064,
        2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    if dof == 0 {
        f64::INFINITY
    } else if dof <= TABLE.len() {
        TABLE[dof - 1]
    } else {
        1.96
    }
}

/// Quantile with linear interpolation; `q` in [0,1]. Sorts a copy.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    assert!((0.0..=1.0).contains(&q));
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = pos - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Streaming mean/variance (Welford). Used by the bench harness so timing
/// loops do not allocate.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// The one bench timing loop: run `warmups` untimed calls, then `iters`
/// timed calls, returning the per-call wall times in **milliseconds** as
/// a [`Welford`]. Shared by the harness-free benches and `bench_report`
/// so a methodology change (warmup count, mean-vs-min reporting — the
/// regression gate compares these numbers) happens in one place.
pub fn time_ms<F: FnMut()>(warmups: usize, iters: usize, mut f: F) -> Welford {
    for _ in 0..warmups {
        f();
    }
    let mut w = Welford::new();
    for _ in 0..iters.max(1) {
        let t0 = std::time::Instant::now();
        f();
        w.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        // Sample std of this classic set is ~2.138.
        assert!((std_dev(&xs) - 2.1380899).abs() < 1e-5);
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(std_dev(&[3.0]), 0.0);
        assert_eq!(ci95_half_width(&[3.0]), 0.0);
    }

    #[test]
    fn ci95_known_value() {
        // n=5, std=1 -> hw = 2.776 / sqrt(5).
        let xs = [0.0, 1.0, 2.0, 3.0, 4.0]; // std = sqrt(2.5)
        let expected = 2.776 * (2.5f64).sqrt() / (5f64).sqrt();
        assert!((ci95_half_width(&xs) - expected).abs() < 1e-9);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.5, -3.0, 4.0, 0.5, 7.25];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std_dev() - std_dev(&xs)).abs() < 1e-12);
        assert_eq!(w.min(), -3.0);
        assert_eq!(w.max(), 7.25);
        assert_eq!(w.count(), 6);
    }
}
