//! Tiny command-line parser (no `clap` offline).
//!
//! Grammar: `fedpara <subcommand> [positionals] [--flag] [--key value]...`
//! `--key=value` is also accepted. Unknown flags are an error so typos
//! surface immediately.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positionals: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Option/flag names this command accepts (for validation + help).
    known: Vec<(String, String)>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, String> {
        let mut a = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    return Err("bare '--' is not supported".into());
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else {
                    // Peek: if the next token is not another flag, treat it
                    // as this option's value; otherwise it's a boolean flag.
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            a.options.insert(stripped.to_string(), v);
                        }
                        _ => a.flags.push(stripped.to_string()),
                    }
                }
            } else if a.subcommand.is_none() {
                a.subcommand = Some(tok);
            } else {
                a.positionals.push(tok);
            }
        }
        Ok(a)
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    /// Declare a known option (for `validate` + help text).
    pub fn declare(&mut self, name: &str, help: &str) -> &mut Self {
        self.known.push((name.to_string(), help.to_string()));
        self
    }

    /// Error on any option/flag that was never declared.
    pub fn validate(&self) -> Result<(), String> {
        for k in self.options.keys().chain(self.flags.iter()) {
            if !self.known.iter().any(|(n, _)| n == k) {
                let mut msg = format!("unknown option --{k}. known options:");
                for (n, h) in &self.known {
                    msg.push_str(&format!("\n  --{n:<18} {h}"));
                }
                return Err(msg);
            }
        }
        Ok(())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects a number, got '{v}'")),
        }
    }

    pub fn help_text(&self) -> String {
        let mut s = String::new();
        for (n, h) in &self.known {
            s.push_str(&format!("  --{n:<18} {h}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse(&["exp", "table2", "extra"]);
        assert_eq!(a.subcommand.as_deref(), Some("exp"));
        assert_eq!(a.positionals, vec!["table2", "extra"]);
    }

    #[test]
    fn options_both_syntaxes() {
        let a = parse(&["run", "--rounds", "50", "--gamma=0.3"]);
        assert_eq!(a.get("rounds"), Some("50"));
        assert_eq!(a.get("gamma"), Some("0.3"));
        assert_eq!(a.get_usize("rounds", 0).unwrap(), 50);
        assert_eq!(a.get_f64("gamma", 0.0).unwrap(), 0.3);
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse(&["run", "--verbose"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["run", "--verbose", "--rounds", "10"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("rounds"), Some("10"));
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse(&["run", "--rounds", "ten"]);
        assert!(a.get_usize("rounds", 5).is_err());
        assert_eq!(a.get_usize("epochs", 5).unwrap(), 5);
        assert_eq!(a.get_or("scale", "tiny"), "tiny");
    }

    #[test]
    fn validate_rejects_unknown() {
        let mut a = parse(&["run", "--boguss", "1"]);
        a.declare("rounds", "number of rounds");
        assert!(a.validate().is_err());
        let mut b = parse(&["run", "--rounds", "1"]);
        b.declare("rounds", "number of rounds");
        assert!(b.validate().is_ok());
    }
}
