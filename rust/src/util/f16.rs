//! IEEE 754 binary16 (half precision) conversion.
//!
//! Used by the FedPAQ-style uplink quantizer (paper Supp. D.3: quantize the
//! uploaded model from fp32 to fp16). No `half` crate offline, so we do the
//! bit manipulation ourselves. Round-to-nearest-even, with proper handling
//! of subnormals, infinities and NaN.

/// Convert an f32 to its binary16 bit pattern (round-to-nearest-even).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN. Preserve NaN-ness with a quiet mantissa bit.
        return if mant == 0 { sign | 0x7C00 } else { sign | 0x7E00 };
    }

    // Unbiased exponent, then re-bias for half (15).
    let unbiased = exp - 127;
    let half_exp = unbiased + 15;

    if half_exp >= 0x1F {
        // Overflow -> infinity.
        return sign | 0x7C00;
    }

    if half_exp <= 0 {
        // Subnormal half or underflow to zero.
        if half_exp < -10 {
            return sign; // Rounds to zero even from the largest mantissa.
        }
        // Add the implicit leading 1, then shift right.
        let mant = mant | 0x0080_0000;
        let shift = (14 - half_exp) as u32; // 14..24
        let half_mant = mant >> shift;
        // Round to nearest even on the dropped bits.
        let rem = mant & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let mut h = half_mant as u16;
        if rem > halfway || (rem == halfway && (h & 1) == 1) {
            h += 1; // May carry into the exponent; that is correct behaviour.
        }
        return sign | h;
    }

    // Normal number: keep top 10 mantissa bits, round-to-nearest-even.
    let half_mant = (mant >> 13) as u16;
    let rem = mant & 0x1FFF;
    let mut h = sign | ((half_exp as u16) << 10) | half_mant;
    if rem > 0x1000 || (rem == 0x1000 && (h & 1) == 1) {
        h = h.wrapping_add(1); // Mantissa carry rolls into exponent correctly.
    }
    h
}

/// Convert a binary16 bit pattern back to f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;

    let bits = if exp == 0 {
        if mant == 0 {
            sign // +-0
        } else {
            // Subnormal: value = mant * 2^-24. Normalize so the leading 1
            // sits at bit 10; after s left-shifts the unbiased exponent is
            // -14 - s, i.e. an f32 exponent field of 113 - s. The shift
            // count comes straight from the bit position of the leading 1
            // (mant has 1..=10 significant bits, so `s` is 1..=10).
            let s = mant.leading_zeros() as i32 - 21;
            let m = (mant << s) & 0x03FF;
            let exp32 = (113 - s) as u32;
            sign | (exp32 << 23) | (m << 13)
        }
    } else if exp == 0x1F {
        if mant == 0 {
            sign | 0x7F80_0000 // Inf
        } else {
            sign | 0x7FC0_0000 | (mant << 13) // NaN
        }
    } else {
        let exp32 = exp + 127 - 15;
        sign | (exp32 << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Quantize a slice through fp16 and back (the FedPAQ uplink transform).
pub fn quantize_roundtrip(xs: &[f32]) -> Vec<f32> {
    xs.iter().map(|&x| f16_bits_to_f32(f32_to_f16_bits(x))).collect()
}

/// In-place [`quantize_roundtrip`] — the uplink path uses this so the
/// steady-state round loop quantizes without allocating a second vector.
pub fn quantize_roundtrip_in_place(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = f16_bits_to_f32(f32_to_f16_bits(*x));
    }
}

/// Quantize a slice into fp16 bit patterns, reusing `bits` (cleared,
/// reserved and refilled) — the reusable-buffer bit-level counterpart of
/// [`pack`] for transports that carry `u16`s directly. The coordinator's
/// *simulated* uplink only needs the dequantized values and uses
/// [`quantize_roundtrip_in_place`] instead.
pub fn quantize(xs: &[f32], bits: &mut Vec<u16>) {
    bits.clear();
    bits.reserve(xs.len());
    bits.extend(xs.iter().map(|&x| f32_to_f16_bits(x)));
}

/// Decode fp16 bit patterns into `out` (cleared, reserved and refilled) —
/// the inverse of [`quantize`], mirroring [`unpack`] at the `u16` level.
pub fn dequantize(bits: &[u16], out: &mut Vec<f32>) {
    out.clear();
    out.reserve(bits.len());
    out.extend(bits.iter().map(|&h| f16_bits_to_f32(h)));
}

/// Pack a slice of f32 into fp16 bytes (what actually goes on the wire).
pub fn pack(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 2);
    for &x in xs {
        out.extend_from_slice(&f32_to_f16_bits(x).to_le_bytes());
    }
    out
}

/// Unpack fp16 bytes back into f32.
pub fn unpack(bytes: &[u8]) -> Vec<f32> {
    assert!(bytes.len() % 2 == 0, "fp16 byte stream must be even length");
    bytes
        .chunks_exact(2)
        .map(|c| f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for &x in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 0.25, 1024.0] {
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            assert_eq!(x, y, "{x} -> {y}");
            // Sign of zero must be preserved.
            assert_eq!(x.is_sign_negative(), y.is_sign_negative());
        }
    }

    #[test]
    fn known_bit_patterns() {
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF); // Largest normal half.
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16_bits(6.103515625e-5), 0x0400); // Smallest normal.
        assert_eq!(f32_to_f16_bits(5.9604645e-8), 0x0001); // Smallest subnormal.
    }

    #[test]
    fn overflow_to_infinity() {
        assert_eq!(f32_to_f16_bits(1e6), 0x7C00);
        assert_eq!(f32_to_f16_bits(-1e6), 0xFC00);
        assert!(f16_bits_to_f32(0x7C00).is_infinite());
    }

    #[test]
    fn nan_stays_nan() {
        let h = f32_to_f16_bits(f32::NAN);
        assert!(f16_bits_to_f32(h).is_nan());
    }

    #[test]
    fn underflow_to_zero() {
        assert_eq!(f32_to_f16_bits(1e-10), 0x0000);
        assert_eq!(f32_to_f16_bits(-1e-10), 0x8000);
    }

    #[test]
    fn relative_error_bound_for_normals() {
        // Half has 11 significand bits -> rel error <= 2^-11 for values in
        // the normal range. This is the property the Table-12 quantizer
        // relies on.
        let mut state = 0x1234_5678_9abc_def0u64;
        for _ in 0..10_000 {
            let r = crate::util::rng::splitmix64(&mut state);
            // Random values across the half-normal range.
            let x = ((r >> 40) as f32 / (1u64 << 24) as f32 - 0.5) * 100.0;
            if x.abs() < 6.2e-5 {
                continue;
            }
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            let rel = ((x - y) / x).abs();
            assert!(rel <= 1.0 / 2048.0 + 1e-7, "x={x} y={y} rel={rel}");
        }
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between two representable halves;
        // RNE keeps the even mantissa (i.e. rounds down to 1.0).
        let x = 1.0f32 + 2.0f32.powi(-11);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(x)), 1.0);
        // 1 + 3*2^-11 is halfway and must round *up* to even.
        let x = 1.0f32 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(f32_to_f16_bits(x), 0x3C02);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let xs: Vec<f32> = (0..257).map(|i| (i as f32 - 128.0) * 0.37).collect();
        let packed = pack(&xs);
        assert_eq!(packed.len(), xs.len() * 2);
        let back = unpack(&packed);
        let direct = quantize_roundtrip(&xs);
        assert_eq!(back, direct);
    }

    #[test]
    fn subnormal_roundtrips() {
        // All 1024 subnormal half patterns decode+encode to themselves.
        for bits in 1u16..0x0400 {
            let f = f16_bits_to_f32(bits);
            assert_eq!(f32_to_f16_bits(f), bits, "bits={bits:#06x} f={f}");
        }
    }

    #[test]
    fn exhaustive_bit_pattern_roundtrip() {
        // Every one of the 65,536 half patterns: decode to f32 and
        // re-encode. Non-NaN patterns (zeros, subnormals, normals,
        // infinities — signs included) must come back bit-exactly; NaN
        // payloads are canonicalized by the encoder but must stay NaN with
        // the sign preserved.
        for bits in 0u16..=u16::MAX {
            let f = f16_bits_to_f32(bits);
            let back = f32_to_f16_bits(f);
            let exp = (bits >> 10) & 0x1F;
            let mant = bits & 0x03FF;
            if exp == 0x1F && mant != 0 {
                assert!(f.is_nan(), "bits={bits:#06x} decoded to non-NaN {f}");
                assert_eq!(back & 0x8000, bits & 0x8000, "NaN sign lost: {bits:#06x}");
                assert_eq!(back & 0x7C00, 0x7C00, "NaN exponent lost: {bits:#06x}");
                assert_ne!(back & 0x03FF, 0, "NaN collapsed to Inf: {bits:#06x}");
            } else {
                assert_eq!(back, bits, "bits={bits:#06x} f={f} back={back:#06x}");
            }
        }
    }

    #[test]
    fn slice_quantize_dequantize_match_scalar_path() {
        let xs: Vec<f32> = (0..300)
            .map(|i| ((i as f32) - 150.0) * 0.421)
            .chain([0.0, -0.0, 1e-7, -1e-7, f32::INFINITY, 65504.0])
            .collect();
        let mut bits = Vec::new();
        quantize(&xs, &mut bits);
        assert_eq!(bits.len(), xs.len());
        let mut back = Vec::new();
        dequantize(&bits, &mut back);
        assert_eq!(back, quantize_roundtrip(&xs));
        // Reuse: a second call with different content fully replaces it.
        quantize(&xs[..5], &mut bits);
        assert_eq!(bits.len(), 5);
        // In-place roundtrip equals the allocating one.
        let mut inplace = xs.clone();
        quantize_roundtrip_in_place(&mut inplace);
        assert_eq!(inplace, quantize_roundtrip(&xs));
    }
}
