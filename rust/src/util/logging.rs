//! Leveled stderr logger (no `log`/`env_logger` wiring needed offline).
//!
//! Level comes from `FEDPARA_LOG` (error|warn|info|debug|trace) or
//! `set_level`. Macros `log_info!` etc. live at crate root via
//! `#[macro_export]`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info default.
static INIT: std::sync::Once = std::sync::Once::new();
static mut START: Option<Instant> = None;

pub fn init_from_env() {
    INIT.call_once(|| {
        unsafe { START = Some(Instant::now()) };
        if let Ok(v) = std::env::var("FEDPARA_LOG") {
            if let Some(l) = parse_level(&v) {
                LEVEL.store(l as u8, Ordering::Relaxed);
            }
        }
    });
}

pub fn parse_level(s: &str) -> Option<Level> {
    match s.to_ascii_lowercase().as_str() {
        "error" => Some(Level::Error),
        "warn" | "warning" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

pub fn set_level(l: Level) {
    init_from_env();
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    init_from_env();
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Seconds since logger init, for relative timestamps.
pub fn elapsed_secs() -> f64 {
    init_from_env();
    // Safe: START is written once inside Once.
    unsafe {
        let ptr = std::ptr::addr_of!(START);
        (*ptr).map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0)
    }
}

pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{:>9.3}s {} {}] {}", elapsed_secs(), tag, module, msg);
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(parse_level("info"), Some(Level::Info));
        assert_eq!(parse_level("WARN"), Some(Level::Warn));
        assert_eq!(parse_level("warning"), Some(Level::Warn));
        assert_eq!(parse_level("nope"), None);
    }

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // Restore default for other tests.
    }

    #[test]
    fn ordering() {
        assert!(Level::Error < Level::Trace);
    }
}
