//! Deterministic pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so we implement the generators the
//! coordinator needs: a PCG64-style core generator, SplitMix64 for seeding,
//! Box-Muller gaussians, Marsaglia–Tsang gamma variates, and Dirichlet
//! sampling (used by the non-IID partitioner, following He et al. 2020's
//! Dirichlet(α) client split that the paper adopts).
//!
//! Everything is deterministic given a seed; experiments record their seeds
//! so every table/figure is exactly reproducible.

/// SplitMix64: used to expand a single `u64` seed into stream state.
/// Reference: Steele, Lea, Flood — "Fast Splittable Pseudorandom Number
/// Generators" (OOPSLA 2014).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32 combined into a 64-bit output generator (two streams).
///
/// We keep two independently-seeded 64-bit LCG states and combine their
/// 32-bit PCG outputs; this gives a full 64-bit output word with PCG's
/// statistical quality, which is plenty for simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    state: [u64; 2],
    inc: [u64; 2],
    /// Cached second gaussian from Box-Muller.
    gauss_spare: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Rng {
    /// Create a generator from a seed. Distinct seeds give independent
    /// streams (seed expansion via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut r = Rng {
            state: [splitmix64(&mut sm), splitmix64(&mut sm)],
            inc: [splitmix64(&mut sm) | 1, splitmix64(&mut sm) | 1],
            gauss_spare: None,
        };
        // Warm up so that near-zero seeds decorrelate.
        for _ in 0..4 {
            r.next_u64();
        }
        r
    }

    /// Derive a child generator; `tag` distinguishes siblings. Used to give
    /// each client / round / layer its own stream without sharing state.
    pub fn child(&self, tag: u64) -> Rng {
        // Mix current state with the tag through SplitMix.
        let mut s = self.state[0] ^ self.state[1].rotate_left(17) ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::new(splitmix64(&mut s))
    }

    #[inline]
    fn pcg32(state: &mut u64, inc: u64) -> u32 {
        let old = *state;
        *state = old.wrapping_mul(PCG_MULT).wrapping_add(inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        Self::pcg32(&mut self.state[0], self.inc[0])
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let hi = Self::pcg32(&mut self.state[0], self.inc[0]) as u64;
        let lo = Self::pcg32(&mut self.state[1], self.inc[1]) as u64;
        (hi << 32) | lo
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random bits into the mantissa.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n). Unbiased via Lemire's multiply-shift with
    /// rejection below the `2^64 mod n` threshold.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let threshold = n.wrapping_neg() % n; // 2^64 mod n
        loop {
            let m = (self.next_u64() as u128) * (n as u128);
            if (m as u64) >= threshold {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard gaussian via Box-Muller (with spare caching).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(s) = self.gauss_spare.take() {
            return s;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Gaussian with mean/std.
    pub fn gaussian_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// Gamma(shape, 1) via Marsaglia & Tsang (2000); shape > 0.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0, "gamma shape must be positive");
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            let u = loop {
                let u = self.f64();
                if u > 0.0 {
                    break u;
                }
            };
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.gaussian();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.f64();
            if u < 1.0 - 0.0331 * x * x * x * x {
                return d * v;
            }
            if u > 0.0 && u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Sample from Dirichlet(alpha * 1_k): normalized iid Gamma(alpha)
    /// variates. Used for the non-IID label partition (He et al. 2020b).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        assert!(k > 0);
        let mut v: Vec<f64> = (0..k).map(|_| self.gamma(alpha).max(1e-300)).collect();
        let s: f64 = v.iter().sum();
        for x in v.iter_mut() {
            *x /= s;
        }
        v
    }

    /// Sample an index from an (unnormalized, nonnegative) weight vector.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical weights sum to zero");
        let mut t = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) — **sparse** partial
    /// Fisher-Yates in O(k) time and space.
    ///
    /// The classic implementation materializes `(0..n)` and swaps a
    /// k-prefix into place; at cross-device scale that is an 8 MB
    /// allocation per 1000-of-1M client draw. This version keeps only the
    /// displaced slots in a hash map: position `i` reads as `i` unless a
    /// previous swap moved another value there. It performs the **same**
    /// `below(n - i)` draw sequence as the dense version, so outputs are
    /// bit-identical — every seeded experiment, sampler stream and
    /// partition in the repo is unchanged (pinned by
    /// `sample_indices_matches_dense_reference` below).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut displaced: std::collections::HashMap<usize, usize> =
            std::collections::HashMap::with_capacity(k.min(n / 2 + 1) * 2);
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.below(n - i);
            // Dense equivalent: idx.swap(i, j); out[i] = idx[i].
            let vj = displaced.get(&j).copied().unwrap_or(j);
            let vi = displaced.get(&i).copied().unwrap_or(i);
            displaced.insert(j, vi);
            out.push(vj);
        }
        out
    }

    /// Fill a slice with He-normal (fan_in) initialized f32 values —
    /// mirrors the init the paper uses (He et al. 2015).
    pub fn fill_he_normal(&mut self, out: &mut [f32], fan_in: usize) {
        let std = (2.0 / fan_in.max(1) as f64).sqrt();
        for v in out.iter_mut() {
            *v = self.gaussian_ms(0.0, std) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn child_streams_differ() {
        let root = Rng::new(7);
        let mut a = root.child(0);
        let mut b = root.child(1);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = r.below(7);
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gaussian();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(4);
        for &shape in &[0.3, 0.5, 1.0, 2.5, 10.0] {
            let n = 20_000;
            let mut s = 0.0;
            for _ in 0..n {
                let g = r.gamma(shape);
                assert!(g >= 0.0);
                s += g;
            }
            let mean = s / n as f64;
            assert!(
                (mean - shape).abs() < 0.15 * shape.max(0.5),
                "shape={shape} mean={mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(5);
        for &alpha in &[0.1, 0.5, 1.0, 5.0] {
            let p = r.dirichlet(alpha, 10);
            assert_eq!(p.len(), 10);
            let s: f64 = p.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn dirichlet_concentration_behaviour() {
        // Small alpha -> spiky distributions (high max); large alpha -> flat.
        let mut r = Rng::new(6);
        let trials = 200;
        let avg_max = |r: &mut Rng, alpha: f64| -> f64 {
            (0..trials)
                .map(|_| {
                    r.dirichlet(alpha, 10)
                        .into_iter()
                        .fold(0.0f64, |a, b| a.max(b))
                })
                .sum::<f64>()
                / trials as f64
        };
        let spiky = avg_max(&mut r, 0.1);
        let flat = avg_max(&mut r, 100.0);
        assert!(spiky > 0.5, "spiky={spiky}");
        assert!(flat < 0.2, "flat={flat}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(7);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.5, "ratio={ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let s = r.sample_indices(20, 8);
        assert_eq!(s.len(), 8);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 8);
        assert!(t.iter().all(|&i| i < 20));
    }

    /// The dense O(n) partial Fisher-Yates this repo shipped originally.
    /// The sparse version must reproduce it bit-for-bit (same rng draws,
    /// same outputs) so that every seeded result stays unchanged.
    fn sample_indices_dense(rng: &mut Rng, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + rng.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    #[test]
    fn sample_indices_matches_dense_reference() {
        let mut seed_rng = Rng::new(0xFA57);
        for _ in 0..50 {
            let seed = seed_rng.next_u64();
            let n = 1 + seed_rng.below(500);
            let k = seed_rng.below(n + 1);
            let sparse = Rng::new(seed).sample_indices(n, k);
            let dense = sample_indices_dense(&mut Rng::new(seed), n, k);
            assert_eq!(sparse, dense, "divergence at n={n} k={k} seed={seed}");
            // And the generators are left in the same state (same number
            // of draws consumed).
            let mut a = Rng::new(seed);
            let mut b = Rng::new(seed);
            a.sample_indices(n, k);
            sample_indices_dense(&mut b, n, k);
            assert_eq!(a.next_u64(), b.next_u64(), "rng state diverged at n={n} k={k}");
        }
    }

    /// Property suite at population scale: distinct, in-range,
    /// deterministic, exact-count — with n = 10⁶ and k far below n, which
    /// the dense version could only do via an 8 MB scratch allocation.
    #[test]
    fn sample_indices_population_scale_properties() {
        const N: usize = 1_000_000;
        for (seed, k) in [(1u64, 1usize), (2, 64), (3, 1000), (4, 4096)] {
            let s = Rng::new(seed).sample_indices(N, k);
            assert_eq!(s.len(), k, "exact count");
            assert!(s.iter().all(|&i| i < N), "in range");
            let mut t = s.clone();
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), k, "distinct (seed {seed}, k {k})");
            // Deterministic: same seed reproduces the draw exactly.
            assert_eq!(s, Rng::new(seed).sample_indices(N, k));
        }
        // Different rounds/seeds give different draws.
        assert_ne!(
            Rng::new(7).sample_indices(N, 1000),
            Rng::new(8).sample_indices(N, 1000)
        );
    }

    #[test]
    fn sample_indices_edges() {
        // k == n is a full permutation of 0..n.
        let mut r = Rng::new(11);
        let full = r.sample_indices(9, 9);
        let mut sorted = full.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..9).collect::<Vec<_>>());
        // k == 1 draws a single uniform index; k == 0 draws nothing.
        let one = Rng::new(12).sample_indices(5, 1);
        assert_eq!(one.len(), 1);
        assert!(one[0] < 5);
        assert!(Rng::new(13).sample_indices(5, 0).is_empty());
        // n == 1 has only one possible outcome.
        assert_eq!(Rng::new(14).sample_indices(1, 1), vec![0]);
    }

    #[test]
    #[should_panic(expected = "cannot sample")]
    fn sample_indices_rejects_oversample() {
        Rng::new(15).sample_indices(3, 4);
    }

    #[test]
    fn he_init_variance() {
        let mut r = Rng::new(10);
        let fan_in = 128;
        let mut buf = vec![0f32; 40_000];
        r.fill_he_normal(&mut buf, fan_in);
        let mean = buf.iter().map(|&x| x as f64).sum::<f64>() / buf.len() as f64;
        let var = buf.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / buf.len() as f64;
        let expected = 2.0 / fan_in as f64;
        assert!((var - expected).abs() < 0.15 * expected, "var={var} expected={expected}");
    }
}
