//! Foundation substrates built in-repo (the offline environment provides no
//! crates beyond `xla`/`anyhow`): RNG, JSON, fp16, CLI parsing, thread pool,
//! logging, statistics, and a mini property-test harness.

pub mod cli;
pub mod f16;
pub mod hash;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;
