//! In-repo mini property-testing harness (no `proptest` crate offline).
//!
//! Deliberately small: seeded case generation from `util::rng`, a fixed
//! case count (overridable with FEDPARA_PROPTEST_CASES), and greedy input
//! shrinking for the common generator shapes we use (vectors, sizes).
//! Coordinator invariants (codec roundtrips, partition exactness,
//! aggregation algebra, ...) run through this.

use crate::util::rng::Rng;

/// Number of random cases per property.
pub fn default_cases() -> usize {
    std::env::var("FEDPARA_PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` on `cases` inputs drawn by `gen` from a seeded RNG. On
/// failure, attempt to shrink with `shrink` (smaller candidates first) and
/// panic with the smallest failing input's Debug form.
pub fn check<T, G, S, P>(seed: u64, gen: G, shrink: S, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: Fn(&mut Rng) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> Result<(), String>,
{
    let cases = default_cases();
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // Greedy shrink loop.
            let mut best = (input.clone(), msg);
            loop {
                let mut improved = false;
                for cand in shrink(&best.0) {
                    if let Err(m) = prop(&cand) {
                        best = (cand, m);
                        improved = true;
                        break;
                    }
                }
                if !improved {
                    break;
                }
            }
            panic!(
                "property failed (seed={seed}, case={case})\n  minimal input: {:?}\n  error: {}",
                best.0, best.1
            );
        }
    }
}

/// No shrinking (for inputs where smaller isn't meaningful).
pub fn no_shrink<T: Clone>(_: &T) -> Vec<T> {
    Vec::new()
}

/// Shrink a Vec<f32> by halving and by zeroing elements.
pub fn shrink_vec_f32(v: &Vec<f32>) -> Vec<Vec<f32>> {
    let mut out = Vec::new();
    if v.len() > 1 {
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[v.len() / 2..].to_vec());
    }
    if let Some(i) = v.iter().position(|&x| x != 0.0) {
        let mut z = v.clone();
        z[i] = 0.0;
        out.push(z);
    }
    out
}

/// Shrink a usize toward 1.
pub fn shrink_usize_to_one(n: &usize) -> Vec<usize> {
    let n = *n;
    let mut out = Vec::new();
    if n > 1 {
        out.push(n / 2);
        out.push(n - 1);
    }
    out
}

/// Generate a random f32 vector with magnitudes spanning several decades
/// (exercises numeric edge behaviour better than uniform [0,1)).
pub fn gen_vec_f32(rng: &mut Rng, max_len: usize) -> Vec<f32> {
    let len = 1 + rng.below(max_len.max(1));
    (0..len)
        .map(|_| {
            let mag = 10f64.powf(rng.range_f64(-6.0, 4.0));
            (rng.gaussian() * mag) as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            1,
            |r| gen_vec_f32(r, 32),
            shrink_vec_f32,
            |v| {
                if v.iter().all(|x| x.is_finite()) {
                    Ok(())
                } else {
                    Err("non-finite".into())
                }
            },
        );
    }

    #[test]
    fn failing_property_shrinks() {
        let res = std::panic::catch_unwind(|| {
            check(
                2,
                |r| {
                    let len = 1 + r.below(64);
                    vec![1.0f32; len]
                },
                shrink_vec_f32,
                |v: &Vec<f32>| {
                    if v.len() < 3 {
                        Ok(())
                    } else {
                        Err(format!("len {} >= 3", v.len()))
                    }
                },
            )
        });
        let err = res.expect_err("property should fail");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        // Shrinker halves until just above the threshold.
        assert!(msg.contains("minimal input"), "{msg}");
        assert!(msg.contains("len 3 >= 3") || msg.contains("len 4 >= 3"), "{msg}");
    }

    #[test]
    fn deterministic_given_seed() {
        // Same seed -> same sequence of generated cases.
        let collect = |seed: u64| {
            let mut v = Vec::new();
            let mut rng = Rng::new(seed);
            for _ in 0..5 {
                v.push(gen_vec_f32(&mut rng, 8));
            }
            v
        };
        assert_eq!(collect(7), collect(7));
    }
}
