//! Vendored, offline, API-compatible subset of the `anyhow` crate.
//!
//! Provides exactly the surface this repository uses: [`Error`],
//! [`Result`], the [`anyhow!`] and [`bail!`] macros, [`Error::msg`], the
//! [`Context`] extension trait (`.context(..)` / `.with_context(..)`),
//! `From<E: std::error::Error>` for `?`-conversion, and the `{:#}`
//! alternate format that prints the full context chain.

use std::fmt;

/// A string-backed error with a chain of contexts.
///
/// `chain[0]` is the outermost (most recently attached) context; the last
/// element is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain like anyhow does.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            writeln!(f, "\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                writeln!(f, "    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — a `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a format string, or any
/// displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let n = 3;
        let b = anyhow!("n = {n} and {}", 4);
        assert_eq!(b.to_string(), "n = 3 and 4");
        let c = anyhow!(String::from("owned"));
        assert_eq!(c.to_string(), "owned");
    }

    #[test]
    fn context_chain_and_alternate_format() {
        let r: Result<()> = Err(io_err()).with_context(|| "reading config");
        let e = r.unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "gone");
    }

    #[test]
    fn bail_returns_early() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flag was {flag}");
            }
            Ok(1)
        }
        assert!(f(false).is_ok());
        assert_eq!(f(true).unwrap_err().to_string(), "flag was true");
    }
}
