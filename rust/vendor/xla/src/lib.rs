//! Offline **stub** of the `xla` PJRT bindings.
//!
//! Compiled only under the `pjrt` cargo feature of the `fedpara` crate.
//! It mirrors the API surface `runtime/mod.rs` uses so the PJRT code path
//! keeps compiling offline, but every runtime entry point returns
//! [`Error::Unavailable`]: executing AOT HLO artifacts needs the real XLA
//! C++ runtime. Patch in real bindings to use the PJRT backend
//! (instructions in `vendor/README.md`).

use std::fmt;

/// Error type matching the real bindings' `Result<_, xla::Error>` shape.
#[derive(Debug, Clone)]
pub enum Error {
    /// The stub cannot perform runtime work.
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla stub: {what} requires the real XLA runtime (offline build; \
                 see rust/vendor/README.md)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the runtime passes to literal constructors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
}

/// Host literal (stub: holds nothing).
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(Error::Unavailable("Literal::create_from_shape_and_untyped_data"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable("Literal::to_vec"))
    }
}

/// Device buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Parsed HLO module proto (stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper (stub).
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Compiled executable handle (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle (stub).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }
}
